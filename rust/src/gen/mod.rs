//! Synthetic sparse-matrix generation — the SuiteSparse substitution
//! (DESIGN.md §2).
//!
//! Six structural families span the nonzero-clustering regimes that determine
//! TCU synergy, from diagonal-clustered FEM matrices (the paper's Emilia_923
//! example, high brick density) to scattered power-law web graphs
//! (NotreDame_www, low brick density):
//!
//! * [`banded`] — banded FEM/structural matrices,
//! * [`mesh`] — 2-D/3-D finite-difference Laplacians,
//! * [`rmat`] — recursive-matrix (RMAT) power-law graphs,
//! * [`community`] — block-community social graphs,
//! * [`blockdiag`] — disjoint unions of small dense graphs (TU chemistry
//!   datasets: DD, Yeast, OVCAR-8H, ...),
//! * [`random`] — uniform scatter (worst case for TCUs).
//!
//! [`named`] provides recipes reproducing the node/edge counts and structure
//! class of every matrix in the paper's Tables 3 and 4; [`corpus`] assembles
//! the ~1100-matrix sweep whose synergy mix reproduces Table 2.

pub mod banded;
pub mod blockdiag;
pub mod community;
pub mod corpus;
pub mod mesh;
pub mod named;
pub mod random;
pub mod rmat;

use crate::formats::Coo;
use crate::util::rng::Rng;

/// A structural family, with the parameters that matter to it.
#[derive(Clone, Debug, PartialEq)]
pub enum Family {
    /// `bandwidth`, `band_fill` in (0,1], off-band noise fraction.
    Banded { bandwidth: usize, band_fill: f64, noise: f64 },
    /// 2-D 5-point (`dims=2`) or 3-D 7-point (`dims=3`) Laplacian.
    Mesh { dims: usize },
    /// RMAT with edge factor (avg degree) and skew `a` (a+3b=1 style).
    Rmat { edge_factor: usize, skew: f64 },
    /// `num_communities`, intra-community avg degree, inter fraction.
    Community { communities: usize, intra_degree: usize, inter_frac: f64 },
    /// Disjoint small dense graphs of `unit` nodes, `unit_density` fill.
    BlockDiag { unit: usize, unit_density: f64 },
    /// Uniform random with target average degree.
    Random { avg_degree: usize },
}

/// Deterministic specification of one synthetic matrix.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub name: String,
    pub rows: usize,
    pub family: Family,
    pub seed: u64,
}

impl MatrixSpec {
    /// Generate the matrix. Same spec -> bit-identical matrix.
    pub fn generate(&self) -> Coo {
        let mut rng = Rng::new(self.seed);
        match &self.family {
            Family::Banded { bandwidth, band_fill, noise } => {
                banded::generate(self.rows, *bandwidth, *band_fill, *noise, &mut rng)
            }
            Family::Mesh { dims } => mesh::generate(self.rows, *dims),
            Family::Rmat { edge_factor, skew } => {
                rmat::generate(self.rows, *edge_factor, *skew, &mut rng)
            }
            Family::Community { communities, intra_degree, inter_frac } => {
                community::generate(self.rows, *communities, *intra_degree, *inter_frac, &mut rng)
            }
            Family::BlockDiag { unit, unit_density } => {
                blockdiag::generate(self.rows, *unit, *unit_density, &mut rng)
            }
            Family::Random { avg_degree } => random::generate(self.rows, *avg_degree, &mut rng),
        }
    }

    pub fn family_name(&self) -> &'static str {
        match self.family {
            Family::Banded { .. } => "banded",
            Family::Mesh { .. } => "mesh",
            Family::Rmat { .. } => "rmat",
            Family::Community { .. } => "community",
            Family::BlockDiag { .. } => "blockdiag",
            Family::Random { .. } => "random",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_generation_is_deterministic() {
        let spec = MatrixSpec {
            name: "t".into(),
            rows: 2000,
            family: Family::Rmat { edge_factor: 8, skew: 0.57 },
            seed: 99,
        };
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a, b);
    }

    #[test]
    fn all_families_generate_valid_matrices() {
        let fams = vec![
            Family::Banded { bandwidth: 16, band_fill: 0.5, noise: 0.01 },
            Family::Mesh { dims: 2 },
            Family::Mesh { dims: 3 },
            Family::Rmat { edge_factor: 6, skew: 0.55 },
            Family::Community { communities: 8, intra_degree: 10, inter_frac: 0.1 },
            Family::BlockDiag { unit: 24, unit_density: 0.3 },
            Family::Random { avg_degree: 5 },
        ];
        for (i, family) in fams.into_iter().enumerate() {
            let spec = MatrixSpec { name: format!("f{i}"), rows: 1500, family, seed: i as u64 };
            let coo = spec.generate();
            coo.validate().unwrap();
            assert!(coo.is_normalized());
            assert!(coo.nnz() > 0, "family {i} generated empty matrix");
            assert_eq!(coo.rows, coo.cols, "square matrices expected");
        }
    }
}
