//! Finite-difference mesh Laplacians (2-D 5-point, 3-D 7-point) — the
//! scientific-computing mid-ground: strong diagonal locality but only ~5-7
//! nonzeros per row, giving medium brick density after compaction.

use crate::formats::Coo;

/// Laplacian of a `side^dims` grid, truncated/padded so the matrix has
/// (close to) `target_rows` rows. Deterministic (no RNG needed).
pub fn generate(target_rows: usize, dims: usize) -> Coo {
    assert!(dims == 2 || dims == 3, "dims must be 2 or 3");
    let side = (target_rows as f64).powf(1.0 / dims as f64).round().max(2.0) as usize;
    let n = side.pow(dims as u32);
    let mut coo = Coo::new(n, n);
    let idx2 = |x: usize, y: usize| x * side + y;
    let idx3 = |x: usize, y: usize, z: usize| (x * side + y) * side + z;
    if dims == 2 {
        for x in 0..side {
            for y in 0..side {
                let i = idx2(x, y);
                coo.push(i, i, 4.0);
                if x > 0 {
                    coo.push(i, idx2(x - 1, y), -1.0);
                }
                if x + 1 < side {
                    coo.push(i, idx2(x + 1, y), -1.0);
                }
                if y > 0 {
                    coo.push(i, idx2(x, y - 1), -1.0);
                }
                if y + 1 < side {
                    coo.push(i, idx2(x, y + 1), -1.0);
                }
            }
        }
    } else {
        for x in 0..side {
            for y in 0..side {
                for z in 0..side {
                    let i = idx3(x, y, z);
                    coo.push(i, i, 6.0);
                    if x > 0 {
                        coo.push(i, idx3(x - 1, y, z), -1.0);
                    }
                    if x + 1 < side {
                        coo.push(i, idx3(x + 1, y, z), -1.0);
                    }
                    if y > 0 {
                        coo.push(i, idx3(x, y - 1, z), -1.0);
                    }
                    if y + 1 < side {
                        coo.push(i, idx3(x, y + 1, z), -1.0);
                    }
                    if z > 0 {
                        coo.push(i, idx3(x, y, z - 1), -1.0);
                    }
                    if z + 1 < side {
                        coo.push(i, idx3(x, y, z + 1), -1.0);
                    }
                }
            }
        }
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_point_stencil_interior_row() {
        let coo = generate(100, 2); // side 10
        let d = coo.to_dense();
        // interior node (5,5) -> index 55: diagonal 4, four -1 neighbours
        assert_eq!(d[(55, 55)], 4.0);
        assert_eq!(d[(55, 45)], -1.0);
        assert_eq!(d[(55, 65)], -1.0);
        assert_eq!(d[(55, 54)], -1.0);
        assert_eq!(d[(55, 56)], -1.0);
    }

    #[test]
    fn seven_point_row_counts() {
        let coo = generate(512, 3); // side 8
        let counts = coo.row_counts();
        assert!(counts.iter().all(|&c| (4..=7).contains(&c)));
        // interior nodes have exactly 7
        assert!(counts.iter().any(|&c| c == 7));
    }

    #[test]
    fn symmetric_structure() {
        let coo = generate(225, 2);
        let d = coo.to_dense();
        for i in 0..coo.rows {
            for j in 0..coo.cols {
                assert_eq!(d[(i, j)], d[(j, i)]);
            }
        }
    }

    #[test]
    fn size_close_to_target() {
        let coo = generate(10_000, 2);
        assert!((coo.rows as f64 - 10_000.0).abs() / 10_000.0 < 0.05);
    }
}
