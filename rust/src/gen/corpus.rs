//! The ~1100-matrix synthetic corpus standing in for "all SuiteSparse
//! matrices with more than 10,000 rows" (paper §6.1, Table 2).
//!
//! Family proportions and parameter sweeps are tuned so the resulting
//! Low/Medium/High synergy split approximates the paper's Table 2
//! (666 / 198 / 235 of 1099); `benches/bench_fig9.rs` regenerates the actual
//! counts. Matrix sizes are scaled to this CPU testbed (10k-260k rows) while
//! preserving each family's density and clustering regime.

use crate::gen::{Family, MatrixSpec};
use crate::util::rng::Rng;

/// Corpus scale knob: `Full` ≈ the paper's 1099 matrices, `Quick` is a
/// stratified 1-in-10 subsample for fast iteration and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusScale {
    Full,
    Quick,
}

/// Deterministically enumerate the corpus specs.
pub fn specs(scale: CorpusScale, seed: u64) -> Vec<MatrixSpec> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut push = |name: String, rows: usize, family: Family, rng: &mut Rng| {
        out.push(MatrixSpec { name, rows, family, seed: rng.next_u64() });
    };

    // sizes span the paper's ">10k rows" cut, scaled to the testbed
    let sizes = [10_000, 18_000, 33_000, 60_000, 110_000, 190_000, 260_000];

    // --- scattered / low-synergy families (~60% of the corpus) ---------
    // RMAT web/social graphs: 7 sizes x 4 edge factors x 4 skews = 112
    for (si, &n) in sizes.iter().enumerate() {
        for ef in [3usize, 6, 12, 24] {
            for (ki, skew) in [0.45, 0.55, 0.62, 0.70].into_iter().enumerate() {
                push(format!("rmat_s{si}_e{ef}_k{ki}"), n, Family::Rmat { edge_factor: ef, skew }, &mut rng);
            }
        }
    }
    // Uniform random: 7 sizes x 8 degrees = 56
    for (si, &n) in sizes.iter().enumerate() {
        for deg in [2usize, 3, 4, 6, 8, 12, 16, 24] {
            push(format!("rand_s{si}_d{deg}"), n, Family::Random { avg_degree: deg }, &mut rng);
        }
    }
    // Citation-like tiny-degree random (a second sweep at low degrees, the
    // most common SuiteSparse graph regime): 7 x 6 = 42
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..6 {
            push(format!("cite_s{si}_r{rep}"), n, Family::Random { avg_degree: 2 + rep % 3 }, &mut rng);
        }
    }
    // Sparse communities that stay scattered at brick scale: 7 x 8 = 56
    for (si, &n) in sizes.iter().enumerate() {
        for (ci, comm_frac) in [512usize, 1024, 2048, 4096].into_iter().enumerate() {
            for id in [3usize, 6] {
                push(
                    format!("commlo_s{si}_c{ci}_d{id}"),
                    n,
                    Family::Community { communities: comm_frac.min(n / 8), intra_degree: id, inter_frac: 0.3 },
                    &mut rng,
                );
            }
        }
    }
    // Scattered RMAT replicas for volume (paper's corpus is graph-heavy):
    // 7 sizes x 52 replicas = 364
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..52 {
            let ef = 2 + rep % 7;
            let skew = 0.45 + 0.05 * (rep % 6) as f64;
            push(format!("web_s{si}_r{rep}"), n, Family::Rmat { edge_factor: ef, skew }, &mut rng);
        }
    }

    // --- diagonal-clustered / medium families (~20%) -------------------
    // Mesh Laplacians 2D/3D: 7 x 2 x 8 reps = 112
    for (si, &n) in sizes.iter().enumerate() {
        for dims in [2usize, 3] {
            for rep in 0..8 {
                // offset sizes so reps differ structurally
                let rows = n + rep * (n / 37).max(1);
                push(format!("mesh{dims}d_s{si}_r{rep}"), rows, Family::Mesh { dims }, &mut rng);
            }
        }
    }
    // Thin bands with partial fill: 7 x 12 = 84
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..12 {
            let bw = 2 + rep;
            let fill = 0.25 + 0.05 * (rep % 6) as f64;
            push(
                format!("bandlo_s{si}_r{rep}"),
                n,
                Family::Banded { bandwidth: bw, band_fill: fill, noise: 0.02 },
                &mut rng,
            );
        }
    }

    // --- dense-clustered / high-synergy families (~20%) ----------------
    // FEM-like dense bands (Emilia regime): 7 x 16 = 112
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..16 {
            let bw = 8 + 4 * (rep % 6);
            let fill = 0.55 + 0.06 * (rep % 6) as f64;
            push(
                format!("fem_s{si}_r{rep}"),
                n,
                Family::Banded { bandwidth: bw, band_fill: fill.min(0.95), noise: 0.01 },
                &mut rng,
            );
        }
    }
    // Batched-molecule unions (TU regime): 7 x 12 = 84
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..12 {
            let unit = 12 + 4 * (rep % 5);
            let dens = 0.18 + 0.08 * (rep % 4) as f64;
            push(
                format!("chem_s{si}_r{rep}"),
                n,
                Family::BlockDiag { unit, unit_density: dens },
                &mut rng,
            );
        }
    }
    // Dense communities: 7 x 11 = 77
    for (si, &n) in sizes.iter().enumerate() {
        for rep in 0..11 {
            let comms = (n / (48 + 16 * (rep % 4))).max(4);
            push(
                format!("commhi_s{si}_r{rep}"),
                n,
                Family::Community { communities: comms, intra_degree: 14 + 4 * (rep % 4), inter_frac: 0.08 },
                &mut rng,
            );
        }
    }

    if scale == CorpusScale::Quick {
        out = out.into_iter().step_by(10).collect();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn full_corpus_size_near_paper() {
        let s = specs(CorpusScale::Full, 42);
        assert!(
            (1050..=1150).contains(&s.len()),
            "corpus size {} should approximate the paper's 1099",
            s.len()
        );
    }

    #[test]
    fn quick_is_a_subsample() {
        let full = specs(CorpusScale::Full, 42);
        let quick = specs(CorpusScale::Quick, 42);
        assert!(quick.len() * 9 < full.len() && full.len() < quick.len() * 11);
    }

    #[test]
    fn names_are_unique() {
        let s = specs(CorpusScale::Full, 42);
        let names: HashSet<&str> = s.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names.len(), s.len());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = specs(CorpusScale::Quick, 7);
        let b = specs(CorpusScale::Quick, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.name, y.name);
        }
        let c = specs(CorpusScale::Quick, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.seed != y.seed));
    }

    #[test]
    fn every_family_is_represented() {
        let s = specs(CorpusScale::Full, 42);
        for fam in ["banded", "mesh", "rmat", "community", "blockdiag", "random"] {
            assert!(s.iter().any(|m| m.family_name() == fam), "missing {fam}");
        }
    }

    #[test]
    fn sizes_all_above_10k() {
        let s = specs(CorpusScale::Full, 42);
        assert!(s.iter().all(|m| m.rows >= 10_000));
    }
}
