//! RMAT (recursive matrix) power-law graphs — the NotreDame_www regime:
//! nonzeros scattered across the index space with hub concentration, the
//! low-synergy end of the corpus.

use crate::formats::Coo;
use crate::util::rng::Rng;

/// RMAT graph over `n` (rounded up to a power of two) nodes with
/// `edge_factor` edges per node. `skew` is the probability of the top-left
/// quadrant (`a`); the remaining mass splits as b = c = (1-a)/3 and
/// d = (1-a)/3, the common social-graph parameterization.
pub fn generate(n: usize, edge_factor: usize, skew: f64, rng: &mut Rng) -> Coo {
    assert!(n >= 2 && edge_factor >= 1);
    assert!((0.25..1.0).contains(&skew), "skew must be in [0.25, 1)");
    let levels = (n as f64).log2().ceil() as u32;
    let size = 1usize << levels;
    let a = skew;
    let rest = (1.0 - a) / 3.0;
    let (b, c) = (rest, rest);
    let edges = n * edge_factor;
    let mut coo = Coo::new(size, size);
    for _ in 0..edges {
        let (mut r, mut c_) = (0usize, 0usize);
        for l in (0..levels).rev() {
            let half = 1usize << l;
            let u = rng.f64();
            if u < a {
                // top-left
            } else if u < a + b {
                c_ += half;
            } else if u < a + b + c {
                r += half;
            } else {
                r += half;
                c_ += half;
            }
        }
        coo.push(r, c_, rng.nz_value());
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_is_power_of_two_at_least_n() {
        let mut rng = Rng::new(1);
        let coo = generate(1000, 4, 0.57, &mut rng);
        assert_eq!(coo.rows, 1024);
    }

    #[test]
    fn edge_count_near_target() {
        let mut rng = Rng::new(2);
        let coo = generate(4096, 8, 0.57, &mut rng);
        let target = 4096 * 8;
        // duplicates collapse, so nnz <= target but should retain most edges
        assert!(coo.nnz() <= target);
        assert!(coo.nnz() > target / 2, "nnz {} vs target {target}", coo.nnz());
    }

    #[test]
    fn skew_concentrates_in_low_indices() {
        let mut rng = Rng::new(3);
        let coo = generate(4096, 8, 0.7, &mut rng);
        let low = (0..coo.nnz())
            .filter(|&i| (coo.row_idx[i] as usize) < coo.rows / 2)
            .count();
        assert!(
            low as f64 > coo.nnz() as f64 * 0.6,
            "top half should dominate with skew 0.7: {low}/{}",
            coo.nnz()
        );
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let mut rng = Rng::new(4);
        let coo = generate(8192, 8, 0.6, &mut rng);
        let counts = coo.row_counts();
        let max_deg = *counts.iter().max().unwrap() as f64;
        let mean_deg = coo.nnz() as f64 / coo.rows as f64;
        assert!(max_deg > mean_deg * 8.0, "expected hubs: max {max_deg}, mean {mean_deg}");
    }
}
