//! Banded FEM/structural matrices — the Emilia_923 regime: nonzeros
//! clustered around the diagonal, so HRPB bricks near the diagonal are dense
//! (the paper reports ~20% average brick density for Emilia_923).

use crate::formats::Coo;
use crate::util::rng::Rng;

/// `n x n` matrix with nonzeros inside a band of half-width `bandwidth`,
/// each in-band element present with probability `band_fill`, plus a
/// `noise` fraction of uniformly scattered off-band nonzeros.
pub fn generate(n: usize, bandwidth: usize, band_fill: f64, noise: f64, rng: &mut Rng) -> Coo {
    assert!(n > 0 && bandwidth > 0);
    assert!((0.0..=1.0).contains(&band_fill));
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        let lo = r.saturating_sub(bandwidth);
        let hi = (r + bandwidth + 1).min(n);
        for c in lo..hi {
            if r == c || rng.chance(band_fill) {
                coo.push(r, c, rng.nz_value());
            }
        }
    }
    let extra = (coo.nnz() as f64 * noise) as usize;
    for _ in 0..extra {
        coo.push(rng.below(n), rng.below(n), rng.nz_value());
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_always_present() {
        let mut rng = Rng::new(1);
        let coo = generate(500, 8, 0.3, 0.0, &mut rng);
        let d = coo.to_dense();
        for i in 0..500 {
            assert_ne!(d[(i, i)], 0.0, "diagonal hole at {i}");
        }
    }

    #[test]
    fn band_confinement_without_noise() {
        let mut rng = Rng::new(2);
        let bw = 5;
        let coo = generate(300, bw, 0.8, 0.0, &mut rng);
        for i in 0..coo.nnz() {
            let (r, c) = (coo.row_idx[i] as i64, coo.col_idx[i] as i64);
            assert!((r - c).abs() <= bw as i64);
        }
    }

    #[test]
    fn fill_scales_nnz() {
        let mut rng = Rng::new(3);
        let sparse = generate(1000, 10, 0.1, 0.0, &mut rng);
        let dense = generate(1000, 10, 0.9, 0.0, &mut rng);
        assert!(dense.nnz() > sparse.nnz() * 3);
    }

    #[test]
    fn noise_adds_offband() {
        let mut rng = Rng::new(4);
        let coo = generate(2000, 4, 0.5, 0.2, &mut rng);
        let offband = (0..coo.nnz())
            .filter(|&i| {
                let (r, c) = (coo.row_idx[i] as i64, coo.col_idx[i] as i64);
                (r - c).abs() > 4
            })
            .count();
        assert!(offband > 0);
    }
}
