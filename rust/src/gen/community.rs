//! Block-community graphs (stochastic block model): dense intra-community
//! clusters + sparse inter-community edges. After HRPB compaction the
//! clusters produce moderately dense bricks — the medium-synergy regime.

use crate::formats::Coo;
use crate::util::rng::Rng;

/// `n`-node graph split into `communities` equal groups; each node gets
/// ~`intra_degree` edges inside its group, and a fraction `inter_frac` of
/// edges rewired to random other groups.
pub fn generate(
    n: usize,
    communities: usize,
    intra_degree: usize,
    inter_frac: f64,
    rng: &mut Rng,
) -> Coo {
    assert!(communities >= 1 && n >= communities);
    let gsize = n / communities;
    assert!(gsize >= 2, "community size too small");
    let mut coo = Coo::new(n, n);
    for v in 0..n {
        let g = (v / gsize).min(communities - 1);
        let glo = g * gsize;
        let ghi = if g == communities - 1 { n } else { glo + gsize };
        for _ in 0..intra_degree {
            if rng.chance(inter_frac) {
                coo.push(v, rng.below(n), rng.nz_value());
            } else {
                coo.push(v, rng.range(glo, ghi), rng.nz_value());
            }
        }
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_edges_dominate() {
        let mut rng = Rng::new(1);
        let n = 4000;
        let comm = 10;
        let coo = generate(n, comm, 12, 0.05, &mut rng);
        let gsize = n / comm;
        let intra = (0..coo.nnz())
            .filter(|&i| coo.row_idx[i] as usize / gsize == coo.col_idx[i] as usize / gsize)
            .count();
        assert!(intra as f64 > coo.nnz() as f64 * 0.85);
    }

    #[test]
    fn inter_frac_one_is_uniform() {
        let mut rng = Rng::new(2);
        let coo = generate(2000, 4, 8, 1.0, &mut rng);
        let gsize = 500;
        let intra = (0..coo.nnz())
            .filter(|&i| coo.row_idx[i] as usize / gsize == coo.col_idx[i] as usize / gsize)
            .count();
        // uniform target hits own community ~1/4 of the time
        let frac = intra as f64 / coo.nnz() as f64;
        assert!(frac < 0.4, "frac={frac}");
    }

    #[test]
    fn all_rows_have_edges() {
        let mut rng = Rng::new(3);
        let coo = generate(1000, 5, 6, 0.1, &mut rng);
        let counts = coo.row_counts();
        assert!(counts.iter().all(|&c| c >= 1));
    }
}
