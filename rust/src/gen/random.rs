//! Uniformly scattered sparse matrices — no clustering at all, the
//! worst case for brick compaction (synergy floor: α -> 1/16).

use crate::formats::Coo;
use crate::util::rng::Rng;

/// `n x n` matrix with `avg_degree` uniformly-placed nonzeros per row.
pub fn generate(n: usize, avg_degree: usize, rng: &mut Rng) -> Coo {
    assert!(n > 0 && avg_degree >= 1);
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for _ in 0..avg_degree {
            coo.push(r, rng.below(n), rng.nz_value());
        }
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_close_to_target() {
        let mut rng = Rng::new(1);
        let coo = generate(5000, 8, &mut rng);
        let mean = coo.nnz() as f64 / 5000.0;
        assert!((mean - 8.0).abs() < 0.5, "mean degree {mean}");
    }

    #[test]
    fn columns_spread_out() {
        let mut rng = Rng::new(2);
        let coo = generate(4000, 6, &mut rng);
        // count column-index mass in each quarter of the index space
        let mut quarters = [0usize; 4];
        for &c in &coo.col_idx {
            quarters[(c as usize * 4 / coo.cols).min(3)] += 1;
        }
        let total = coo.nnz() as f64;
        for q in quarters {
            let frac = q as f64 / total;
            assert!((frac - 0.25).abs() < 0.05, "uniformity violated: {frac}");
        }
    }
}
