//! Disjoint unions of small dense graphs — the TU chemistry-dataset regime
//! (DD, Yeast, YeastH, OVCAR-8H, PROTEINS_full in the paper's Tables 3/4):
//! thousands of small molecules batched into one block-diagonal adjacency
//! matrix. Small dense diagonal blocks pack into very dense HRPB bricks,
//! the high-synergy end of the corpus.

use crate::formats::Coo;
use crate::util::rng::Rng;

/// Block-diagonal matrix of `n` total rows made of consecutive `unit`-sized
/// blocks (the last may be smaller), each filled with density `unit_density`
/// plus a guaranteed diagonal.
pub fn generate(n: usize, unit: usize, unit_density: f64, rng: &mut Rng) -> Coo {
    assert!(unit >= 1 && n >= 1);
    assert!((0.0..=1.0).contains(&unit_density));
    let mut coo = Coo::new(n, n);
    let mut start = 0;
    while start < n {
        let end = (start + unit).min(n);
        for r in start..end {
            coo.push(r, r, rng.nz_value());
            for c in start..end {
                if c != r && rng.chance(unit_density) {
                    coo.push(r, c, rng.nz_value());
                }
            }
        }
        start = end;
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confined_to_diagonal_blocks() {
        let mut rng = Rng::new(1);
        let unit = 20;
        let coo = generate(1000, unit, 0.4, &mut rng);
        for i in 0..coo.nnz() {
            let (r, c) = (coo.row_idx[i] as usize, coo.col_idx[i] as usize);
            assert_eq!(r / unit, c / unit, "off-block entry at ({r},{c})");
        }
    }

    #[test]
    fn density_inside_blocks() {
        let mut rng = Rng::new(2);
        let unit = 16;
        let n = 1600;
        let coo = generate(n, unit, 0.5, &mut rng);
        let slots = (n / unit) * unit * unit;
        let fill = coo.nnz() as f64 / slots as f64;
        assert!((fill - 0.5).abs() < 0.1, "fill={fill}");
    }

    #[test]
    fn tail_block_handled() {
        let mut rng = Rng::new(3);
        let coo = generate(50, 16, 0.9, &mut rng); // 3 full + one 2-row block
        coo.validate().unwrap();
        let d = coo.to_dense();
        assert_ne!(d[(49, 49)], 0.0);
        assert_eq!(d[(49, 0)], 0.0);
    }
}
