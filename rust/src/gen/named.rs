//! Named matrix recipes reproducing the graphs evaluated in the paper's
//! Tables 3 and 4 (the TC-GNN benchmark set).
//!
//! We cannot download the originals, so each recipe reproduces the published
//! node count, edge count and structural class (citation network, co-purchase
//! graph, social graph, batched-molecule union). Per DESIGN.md §2 this
//! preserves what the SpMM comparison actually depends on: rows, nnz/row, and
//! nonzero clustering at brick granularity.

use crate::gen::{Family, MatrixSpec};

/// One Table-3/4 matrix: recipe + published metadata.
#[derive(Clone, Debug)]
pub struct NamedMatrix {
    pub name: &'static str,
    /// Published node count.
    pub nodes: usize,
    /// Published (directed) edge count.
    pub edges: usize,
    pub spec: MatrixSpec,
}

fn spec(name: &'static str, nodes: usize, family: Family, seed: u64) -> MatrixSpec {
    MatrixSpec { name: name.to_string(), rows: nodes, family, seed }
}

/// All matrices from Tables 3 and 4 of the paper, in table order.
pub fn all() -> Vec<NamedMatrix> {
    let mut v = Vec::new();
    let mut add = |name: &'static str, nodes: usize, edges: usize, family: Family| {
        let seed = 0x7ab1e34 ^ (name.len() as u64) << 32 ^ nodes as u64;
        v.push(NamedMatrix { name, nodes, edges, spec: spec(name, nodes, family, seed) });
    };

    let ef = |nodes: usize, edges: usize| (edges as f64 / nodes as f64).round().max(1.0) as usize;

    // Co-purchase graphs (amazon*): moderate power-law, some locality.
    add("amazon0505", 410_236, 3_356_824,
        Family::Community { communities: 4096, intra_degree: ef(410_236, 3_356_824), inter_frac: 0.25 });
    add("amazon0601", 403_394, 3_387_388,
        Family::Community { communities: 4096, intra_degree: ef(403_394, 3_387_388), inter_frac: 0.25 });
    // Social / web graphs: heavy power-law scatter.
    add("artist", 50_515, 1_638_396, Family::Rmat { edge_factor: ef(50_515, 1_638_396), skew: 0.57 });
    // Citation networks: tiny degree, scattered.
    add("citeseer", 3_327, 9_104, Family::Random { avg_degree: 3 });
    add("com-amazon", 334_863, 925_872,
        Family::Community { communities: 8192, intra_degree: ef(334_863, 925_872), inter_frac: 0.2 });
    add("cora", 2_708, 10_556, Family::Random { avg_degree: 4 });
    // Batched molecule unions (TU datasets): small dense diagonal blocks.
    add("DD", 334_925, 1_686_092, Family::BlockDiag { unit: 24, unit_density: 0.21 });
    add("OVCAR-8H", 1_890_931, 3_946_402, Family::BlockDiag { unit: 20, unit_density: 0.10 });
    add("ppi", 56_944, 818_716, Family::Rmat { edge_factor: ef(56_944, 818_716), skew: 0.55 });
    add("PROTEINS_full", 43_471, 162_088, Family::BlockDiag { unit: 40, unit_density: 0.093 });
    add("pubmed", 19_717, 88_648, Family::Random { avg_degree: 4 });
    add("soc-BlogCatalog", 88_784, 2_093_195,
        Family::Rmat { edge_factor: ef(88_784, 2_093_195), skew: 0.6 });
    add("Yeast", 1_714_644, 3_636_546, Family::BlockDiag { unit: 22, unit_density: 0.096 });
    add("YeastH", 3_139_988, 6_487_230, Family::BlockDiag { unit: 22, unit_density: 0.094 });
    v
}

/// The Table-3 subset (evaluated at n = 32/64/128 on the RTX 4090).
pub fn table3() -> Vec<NamedMatrix> {
    all()
}

/// The Table-4 subset: the paper's Table 4 repeats Table 3's matrices minus
/// `ppi` (13 rows), evaluated at n = 32/128/512 on the A100.
pub fn table4() -> Vec<NamedMatrix> {
    all().into_iter().filter(|m| m.name != "ppi").collect()
}

/// Look a named matrix up (used by the CLI).
pub fn by_name(name: &str) -> Option<NamedMatrix> {
    all().into_iter().find(|m| m.name.eq_ignore_ascii_case(name))
}

/// A scaled-down variant for tests and quick examples: same structure,
/// `scale`-fold fewer rows.
pub fn scaled(name: &str, scale: usize) -> Option<MatrixSpec> {
    by_name(name).map(|m| {
        let mut s = m.spec.clone();
        s.rows = (s.rows / scale).max(64);
        if let Family::Community { ref mut communities, .. } = s.family {
            *communities = (*communities / scale).max(4);
        }
        s.name = format!("{}@1/{}", m.name, scale);
        s
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fourteen_table3_matrices() {
        assert_eq!(table3().len(), 14);
        assert_eq!(table4().len(), 13);
    }

    #[test]
    fn edge_counts_within_tolerance() {
        // generate the small ones and check nnz lands near the published
        // edge count (duplicate collapse makes generated <= target)
        for m in all() {
            if m.nodes > 60_000 {
                continue; // keep the unit test fast; corpus test covers large
            }
            let coo = m.spec.generate();
            let ratio = coo.nnz() as f64 / m.edges as f64;
            assert!(
                (0.5..=1.6).contains(&ratio),
                "{}: nnz {} vs published edges {} (ratio {ratio:.2})",
                m.name,
                coo.nnz(),
                m.edges
            );
        }
    }

    #[test]
    fn lookup_and_scaling() {
        assert!(by_name("cora").is_some());
        assert!(by_name("CORA").is_some());
        assert!(by_name("nope").is_none());
        let s = scaled("DD", 10).unwrap();
        assert_eq!(s.rows, 33_492);
        let coo = s.generate();
        assert!(coo.nnz() > 0);
    }

    #[test]
    fn chemistry_sets_are_block_diagonal() {
        for name in ["DD", "Yeast", "YeastH", "OVCAR-8H", "PROTEINS_full"] {
            let m = by_name(name).unwrap();
            assert!(matches!(m.spec.family, Family::BlockDiag { .. }), "{name}");
        }
    }
}
