//! Bounded dual-priority admission queue (pure logic, thread-free).
//!
//! Two lanes — high and normal — with strict priority between them (high
//! drains first) and FIFO order within a lane. Total depth is bounded by a
//! hard capacity and the queue tracks the total planner-predicted work it
//! holds, which is the signal the [`super::shed`] policy and the
//! [`super::deadline`] estimator act on. The thread-safe wrapper lives in
//! [`super::AdmissionQueue`].

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Request priority lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Drained before any normal-lane request; never shed by the overload
    /// watermark (only by the hard bound or its own deadline).
    High,
    /// The default lane; shed first under pressure.
    Normal,
}

impl Priority {
    pub const COUNT: usize = 2;

    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
        }
    }

    pub fn all() -> [Priority; Priority::COUNT] {
        [Priority::High, Priority::Normal]
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "high" => Some(Priority::High),
            "normal" | "low" => Some(Priority::Normal),
            _ => None,
        }
    }
}

/// Admission metadata carried by each queued request.
#[derive(Clone, Copy, Debug)]
pub struct Ticket {
    pub priority: Priority,
    /// Planner-predicted execution cost of this request (seconds); feeds the
    /// queued-work watermark and the wait estimate.
    pub cost_s: f64,
    /// Relative deadline from submission; `None` means no deadline (the
    /// admission queue may substitute a configured default).
    pub deadline: Option<Duration>,
    /// Low-synergy (cost-heavy) matrix class — shed first under pressure.
    pub expensive: bool,
    /// When the request entered admission (queue-wait metrics).
    pub enqueued: Instant,
}

impl Ticket {
    pub fn new(priority: Priority, cost_s: f64) -> Ticket {
        Ticket {
            priority,
            cost_s,
            deadline: None,
            expensive: false,
            enqueued: Instant::now(),
        }
    }
}

/// Bounded dual-lane priority queue: high drains before normal, FIFO within
/// a lane, total depth never exceeds `capacity`. The depth counter is
/// derived from the lane lengths so it can never go negative or drift;
/// predicted-work gauges are tracked per lane so a high-priority request's
/// wait estimate can ignore normal-lane backlog it would bypass.
pub struct BoundedDualQueue<T> {
    lanes: [VecDeque<(Ticket, T)>; Priority::COUNT],
    capacity: usize,
    lane_cost_s: [f64; Priority::COUNT],
}

impl<T> BoundedDualQueue<T> {
    pub fn new(capacity: usize) -> BoundedDualQueue<T> {
        BoundedDualQueue {
            lanes: [VecDeque::new(), VecDeque::new()],
            capacity: capacity.max(1),
            lane_cost_s: [0.0; Priority::COUNT],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently queued across both lanes.
    pub fn depth(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn lane_depth(&self, p: Priority) -> usize {
        self.lanes[p.index()].len()
    }

    pub fn is_full(&self) -> bool {
        self.depth() >= self.capacity
    }

    /// Total planner-predicted work queued (seconds).
    pub fn queued_cost_s(&self) -> f64 {
        self.lane_cost_s.iter().sum()
    }

    /// Planner-predicted work queued in one lane (seconds).
    pub fn lane_cost_s(&self, p: Priority) -> f64 {
        self.lane_cost_s[p.index()]
    }

    /// Enqueue on the ticket's lane; returns the item when the hard bound
    /// is reached (the caller decides how to report the rejection).
    pub fn push(&mut self, ticket: Ticket, item: T) -> Result<(), (Ticket, T)> {
        if self.is_full() {
            return Err((ticket, item));
        }
        self.lane_cost_s[ticket.priority.index()] += ticket.cost_s.max(0.0);
        self.lanes[ticket.priority.index()].push_back((ticket, item));
        Ok(())
    }

    /// Dequeue in priority order: the high lane drains completely before the
    /// normal lane is touched; FIFO within a lane.
    pub fn pop(&mut self) -> Option<(Ticket, T)> {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            if let Some((ticket, item)) = lane.pop_front() {
                self.lane_cost_s[i] = (self.lane_cost_s[i] - ticket.cost_s.max(0.0)).max(0.0);
                return Some((ticket, item));
            }
        }
        None
    }

    /// Remove everything, in priority order (shutdown path).
    pub fn drain(&mut self) -> Vec<(Ticket, T)> {
        let mut out = Vec::with_capacity(self.depth());
        while let Some(x) = self.pop() {
            out.push(x);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, UsizeGen};
    use crate::util::rng::Rng;

    fn ticket(p: Priority, cost_s: f64) -> Ticket {
        Ticket::new(p, cost_s)
    }

    #[test]
    fn high_lane_drains_before_normal() {
        let mut q: BoundedDualQueue<u32> = BoundedDualQueue::new(8);
        q.push(ticket(Priority::Normal, 0.0), 1).unwrap();
        q.push(ticket(Priority::High, 0.0), 2).unwrap();
        q.push(ticket(Priority::Normal, 0.0), 3).unwrap();
        q.push(ticket(Priority::High, 0.0), 4).unwrap();
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![2, 4, 1, 3], "high first, FIFO within each lane");
    }

    #[test]
    fn capacity_bound_rejects_and_returns_item() {
        let mut q: BoundedDualQueue<u32> = BoundedDualQueue::new(2);
        assert!(q.push(ticket(Priority::Normal, 0.0), 1).is_ok());
        assert!(q.push(ticket(Priority::High, 0.0), 2).is_ok());
        assert!(q.is_full());
        let (t, item) = q.push(ticket(Priority::High, 0.0), 3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(t.priority, Priority::High);
        // popping frees a slot
        assert!(q.pop().is_some());
        assert!(q.push(ticket(Priority::Normal, 0.0), 4).is_ok());
    }

    #[test]
    fn queued_cost_tracks_pushes_and_pops_per_lane() {
        let mut q: BoundedDualQueue<u32> = BoundedDualQueue::new(8);
        q.push(ticket(Priority::Normal, 2e-3), 1).unwrap();
        q.push(ticket(Priority::High, 3e-3), 2).unwrap();
        assert!((q.queued_cost_s() - 5e-3).abs() < 1e-12);
        assert!((q.lane_cost_s(Priority::High) - 3e-3).abs() < 1e-12);
        assert!((q.lane_cost_s(Priority::Normal) - 2e-3).abs() < 1e-12);
        q.pop().unwrap(); // the high item drains first
        assert!(q.lane_cost_s(Priority::High).abs() < 1e-12);
        assert!((q.queued_cost_s() - 2e-3).abs() < 1e-12);
        q.pop().unwrap();
        assert!(q.queued_cost_s().abs() < 1e-12);
        // negative costs never poison the gauges
        q.push(ticket(Priority::Normal, -1.0), 3).unwrap();
        assert!(q.queued_cost_s() >= 0.0);
        assert!(q.lane_cost_s(Priority::Normal) >= 0.0);
    }

    #[test]
    fn drain_returns_priority_order_and_empties() {
        let mut q: BoundedDualQueue<u32> = BoundedDualQueue::new(8);
        q.push(ticket(Priority::Normal, 0.0), 1).unwrap();
        q.push(ticket(Priority::High, 0.0), 2).unwrap();
        let drained: Vec<u32> = q.drain().into_iter().map(|(_, v)| v).collect();
        assert_eq!(drained, vec![2, 1]);
        assert_eq!(q.depth(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn priority_parse_and_names() {
        assert_eq!(Priority::parse("high"), Some(Priority::High));
        assert_eq!(Priority::parse("NORMAL"), Some(Priority::Normal));
        assert_eq!(Priority::parse("low"), Some(Priority::Normal));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::High.name(), "high");
        assert_ne!(Priority::High.index(), Priority::Normal.index());
    }

    /// Property: under random interleaved push/pop sequences the queue stays
    /// within its bound, tracks depth exactly, drains the high lane first,
    /// and preserves FIFO order within each lane.
    #[test]
    fn prop_queue_invariants_hold_under_random_ops() {
        check("qos queue invariants", 40, &UsizeGen { lo: 0, hi: 1_000_000 }, |&seed| {
            let mut rng = Rng::new(seed as u64);
            let capacity = rng.range(1, 12);
            let mut q: BoundedDualQueue<u64> = BoundedDualQueue::new(capacity);
            let mut model: [std::collections::VecDeque<u64>; 2] =
                [std::collections::VecDeque::new(), std::collections::VecDeque::new()];
            let mut next_token = 0u64;
            for _ in 0..300 {
                if rng.chance(0.6) {
                    let pr = if rng.chance(0.4) { Priority::High } else { Priority::Normal };
                    let t = ticket(pr, rng.f64() * 1e-3);
                    let was_full = q.depth() >= capacity;
                    match q.push(t, next_token) {
                        Ok(()) => {
                            if was_full {
                                return false; // bound violated
                            }
                            model[pr.index()].push_back(next_token);
                        }
                        Err(_) => {
                            if !was_full {
                                return false; // rejected below the bound
                            }
                        }
                    }
                    next_token += 1;
                } else {
                    match q.pop() {
                        Some((t, token)) => {
                            let lane = if model[0].is_empty() { 1 } else { 0 };
                            if t.priority.index() != lane {
                                return false; // normal served while high waited
                            }
                            if model[lane].pop_front() != Some(token) {
                                return false; // FIFO within lane violated
                            }
                        }
                        None => {
                            if !model[0].is_empty() || !model[1].is_empty() {
                                return false;
                            }
                        }
                    }
                }
                if q.depth() != model[0].len() + model[1].len() {
                    return false; // depth counter drifted
                }
                if q.depth() > capacity || q.queued_cost_s() < 0.0 {
                    return false;
                }
                if q.lane_depth(Priority::High) != model[0].len()
                    || q.lane_depth(Priority::Normal) != model[1].len()
                {
                    return false;
                }
            }
            true
        });
    }
}
