//! Deadline propagation: admission-time wait estimation.
//!
//! Each request may carry a relative deadline. At admission the estimated
//! wait is `queued predicted work / drain parallelism` — if that alone
//! already exceeds the deadline, the request is shed immediately with a
//! typed rejection instead of timing out downstream after burning queue
//! space and a kernel launch.

use std::time::Duration;

/// Waits are clamped here so a degenerate cost model can never produce an
/// unrepresentable `Duration`.
const MAX_WAIT_S: f64 = 3600.0;

/// Estimated time a newly admitted request waits before execution starts:
/// the total queued predicted work divided by the drain parallelism.
pub fn estimate_wait(queued_cost_s: f64, drain_parallelism: usize) -> Duration {
    let s = queued_cost_s / drain_parallelism.max(1) as f64;
    if s.is_nan() || s <= 0.0 {
        return Duration::ZERO;
    }
    Duration::from_secs_f64(s.min(MAX_WAIT_S))
}

/// A deadline is unmeetable when the estimated wait alone already exceeds it.
pub fn unmeetable(est_wait: Duration, deadline: Option<Duration>) -> bool {
    matches!(deadline, Some(d) if est_wait > d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_divides_by_parallelism() {
        assert_eq!(estimate_wait(1.0, 1), Duration::from_secs(1));
        assert_eq!(estimate_wait(1.0, 4), Duration::from_millis(250));
        assert_eq!(estimate_wait(0.0, 4), Duration::ZERO);
        // zero parallelism is treated as one drain lane, not a division blowup
        assert_eq!(estimate_wait(2.0, 0), Duration::from_secs(2));
    }

    #[test]
    fn degenerate_costs_clamp() {
        assert_eq!(estimate_wait(f64::NAN, 2), Duration::ZERO);
        assert_eq!(estimate_wait(-5.0, 2), Duration::ZERO);
        assert_eq!(estimate_wait(f64::INFINITY, 2), Duration::from_secs(3600));
        assert_eq!(estimate_wait(1e12, 2), Duration::from_secs(3600));
    }

    #[test]
    fn unmeetable_only_past_the_deadline() {
        let ms = Duration::from_millis;
        assert!(!unmeetable(ms(5), None));
        assert!(!unmeetable(ms(5), Some(ms(5))));
        assert!(!unmeetable(ms(4), Some(ms(5))));
        assert!(unmeetable(ms(6), Some(ms(5))));
    }
}
