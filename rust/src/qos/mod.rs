//! QoS admission layer for the serving path: bounded priority queuing,
//! cost-aware load shedding, and deadline-driven scheduling.
//!
//! The coordinator's batcher manufactures wide batches from concurrent
//! traffic, but under saturation an unbounded ingress grows without bound
//! and tail latency is unmanaged. This module puts a *bounded dual-priority
//! admission queue* in front of the batcher and makes every admission a
//! cost decision driven by the planner's per-matrix predicted execution
//! time (cuTeSpMM's synergy model: high-synergy matrices are cheap on the
//! TCU path, low-synergy ones are expensive):
//!
//! * [`queue`] — the pure bounded dual-lane queue: high before normal,
//!   FIFO within a lane, hard depth bound, queued predicted-work gauge.
//! * [`deadline`] — wait estimation; requests whose estimated wait already
//!   exceeds their deadline are shed immediately with a typed
//!   [`Rejected`]`{est_wait}` error instead of timing out downstream.
//! * [`shed`] — the cost-aware admission rule: past a queued-work
//!   watermark, normal-priority work on expensive (low-synergy) matrices
//!   is rejected first; past twice the watermark all normal work is shed.
//! * [`AdmissionQueue`] — the thread-safe wrapper the coordinator drains in
//!   priority order, with lock-light depth gauges for metrics readers.
//!
//! Surfaces as `Config::qos` in [`crate::coordinator`], `serve --qos` in
//! the CLI, and the `experiment qos` saturation study.

pub mod deadline;
pub mod queue;
pub mod shed;

pub use deadline::estimate_wait;
pub use queue::{BoundedDualQueue, Priority, Ticket};
pub use shed::{admit, RejectReason, Rejected, ShedPolicy};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// QoS admission knobs (`serve --qos`).
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// Hard bound on queued requests across both lanes.
    pub queue_capacity: usize,
    /// Watermark on total outstanding predicted work in seconds — queued
    /// plus already drained into the batcher/dispatch pipeline but not yet
    /// completed. Above it new normal-priority work on expensive
    /// (low-synergy) matrices is shed; above twice it all normal-priority
    /// work is shed. `0.0` disables overload shedding.
    pub watermark_s: f64,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline: Option<Duration>,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            queue_capacity: 256,
            watermark_s: 50e-3,
            default_deadline: None,
        }
    }
}

/// Result of draining the admission queue.
pub enum Pop<T> {
    /// The next request in priority order.
    Item(Ticket, T),
    /// Nothing arrived within the timeout.
    TimedOut,
    /// The queue is closed and empty — stop draining.
    Closed,
}

/// Thread-safe bounded admission queue: producers run the shed policy and
/// enqueue under one lock; a drain loop pops in priority order. Depth
/// gauges are mirrored into atomics so metrics readers never take the
/// queue lock.
pub struct AdmissionQueue<T> {
    inner: Mutex<BoundedDualQueue<T>>,
    available: Condvar,
    policy: ShedPolicy,
    default_deadline: Option<Duration>,
    drain_parallelism: usize,
    closed: AtomicBool,
    depths: [AtomicUsize; Priority::COUNT],
}

impl<T> AdmissionQueue<T> {
    pub fn new(config: QosConfig, drain_parallelism: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            inner: Mutex::new(BoundedDualQueue::new(config.queue_capacity)),
            available: Condvar::new(),
            policy: ShedPolicy {
                capacity: config.queue_capacity.max(1),
                watermark_s: config.watermark_s,
            },
            default_deadline: config.default_deadline,
            drain_parallelism: drain_parallelism.max(1),
            closed: AtomicBool::new(false),
            depths: [AtomicUsize::new(0), AtomicUsize::new(0)],
        }
    }

    /// Lock-free depth gauge for one lane.
    pub fn depth(&self, p: Priority) -> usize {
        self.depths[p.index()].load(Ordering::Relaxed)
    }

    /// Lock-free total depth gauge.
    pub fn total_depth(&self) -> usize {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Run the admission rule and enqueue. A ticket without a deadline gets
    /// the configured default. `downstream_cost_s` is predicted work already
    /// drained out of this queue but not yet completed (batcher, dispatch
    /// channel, executing) — folding it in keeps the wait estimate and the
    /// overload watermark honest about the whole pipeline, not just the
    /// queue. `Err` returns the item with the typed rejection so the caller
    /// can recover the payload.
    pub fn submit(
        &self,
        mut ticket: Ticket,
        item: T,
        downstream_cost_s: f64,
    ) -> Result<(), (Rejected, T)> {
        if ticket.deadline.is_none() {
            ticket.deadline = self.default_deadline;
        }
        let mut q = self.inner.lock().unwrap();
        // checked under the lock: close() drains under the same lock, so an
        // admitted item can never land in an already-drained queue (where
        // its reply would be stranded forever)
        if self.closed.load(Ordering::SeqCst) {
            drop(q);
            let rejected = Rejected {
                reason: RejectReason::Shutdown,
                est_wait: Duration::ZERO,
                priority: ticket.priority,
            };
            return Err((rejected, item));
        }
        let downstream_s = downstream_cost_s.max(0.0);
        // a high-priority request bypasses the normal lane, so its wait
        // estimate only counts the high lane (plus downstream work already
        // past the queue); the overload watermark stays a whole-pipeline
        // pressure signal
        let lane_ahead_s = match ticket.priority {
            Priority::High => q.lane_cost_s(Priority::High),
            Priority::Normal => q.queued_cost_s(),
        };
        let est_wait = estimate_wait(lane_ahead_s + downstream_s, self.drain_parallelism);
        let outstanding_s = q.queued_cost_s() + downstream_s;
        if let Err(reason) = admit(&self.policy, q.depth(), outstanding_s, &ticket, est_wait) {
            drop(q);
            return Err((Rejected { reason, est_wait, priority: ticket.priority }, item));
        }
        let priority = ticket.priority;
        if let Err((t, item)) = q.push(ticket, item) {
            // unreachable in practice: admit() bounds depth below capacity
            drop(q);
            let rejected = Rejected {
                reason: RejectReason::QueueFull,
                est_wait,
                priority: t.priority,
            };
            return Err((rejected, item));
        }
        self.depths[priority.index()].store(q.lane_depth(priority), Ordering::Relaxed);
        drop(q);
        self.available.notify_one();
        Ok(())
    }

    /// Pop the next request in priority order, blocking up to `timeout`.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some((ticket, item)) = q.pop() {
                self.depths[ticket.priority.index()]
                    .store(q.lane_depth(ticket.priority), Ordering::Relaxed);
                return Pop::Item(ticket, item);
            }
            if self.closed.load(Ordering::SeqCst) {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _timed_out) = self.available.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Close the queue for graceful shutdown: later submissions are
    /// rejected with [`RejectReason::Shutdown`], the drain loop sees
    /// [`Pop::Closed`], and everything still queued is returned (in
    /// priority order) so the caller can fail it with typed rejections
    /// instead of dropping it on the floor.
    pub fn close(&self) -> Vec<(Ticket, T)> {
        self.closed.store(true, Ordering::SeqCst);
        let mut q = self.inner.lock().unwrap();
        let rest = q.drain();
        for d in &self.depths {
            d.store(0, Ordering::Relaxed);
        }
        drop(q);
        self.available.notify_all();
        rest
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn config(capacity: usize, watermark_s: f64) -> QosConfig {
        QosConfig { queue_capacity: capacity, watermark_s, default_deadline: None }
    }

    #[test]
    fn submit_pop_roundtrip_in_priority_order() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(config(8, 0.0), 1);
        q.submit(Ticket::new(Priority::Normal, 1e-6), 1, 0.0).unwrap();
        q.submit(Ticket::new(Priority::High, 1e-6), 2, 0.0).unwrap();
        assert_eq!(q.depth(Priority::High), 1);
        assert_eq!(q.total_depth(), 2);
        match q.pop_timeout(Duration::ZERO) {
            Pop::Item(t, v) => {
                assert_eq!(v, 2);
                assert_eq!(t.priority, Priority::High);
            }
            _ => panic!("expected the high-lane item"),
        }
        match q.pop_timeout(Duration::ZERO) {
            Pop::Item(_, v) => assert_eq!(v, 1),
            _ => panic!("expected the normal-lane item"),
        }
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::TimedOut));
    }

    #[test]
    fn hard_bound_sheds_with_typed_rejection() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(config(1, 0.0), 1);
        q.submit(Ticket::new(Priority::Normal, 1e-6), 1, 0.0).unwrap();
        let (rejected, item) = q.submit(Ticket::new(Priority::Normal, 1e-6), 2, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::QueueFull);
        assert_eq!(item, 2);
    }

    #[test]
    fn default_deadline_sheds_unmeetable_requests() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(
            QosConfig {
                queue_capacity: 64,
                watermark_s: 0.0,
                default_deadline: Some(Duration::from_millis(1)),
            },
            1,
        );
        // empty queue: zero estimated wait, admitted
        q.submit(Ticket::new(Priority::Normal, 1.0), 1, 0.0).unwrap();
        // one second of queued predicted work / 1 drain lane >> 1ms deadline
        let (rejected, _) = q.submit(Ticket::new(Priority::Normal, 1e-6), 2, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::DeadlineUnmeetable);
        assert!(rejected.est_wait >= Duration::from_millis(900), "{:?}", rejected.est_wait);
        // an explicit generous deadline overrides the default
        let mut t = Ticket::new(Priority::Normal, 1e-6);
        t.deadline = Some(Duration::from_secs(10));
        q.submit(t, 3, 0.0).unwrap();
    }

    #[test]
    fn watermark_sheds_expensive_normal_work() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(config(64, 1e-3), 1);
        q.submit(Ticket::new(Priority::Normal, 1.5e-3), 1, 0.0).unwrap();
        let mut expensive = Ticket::new(Priority::Normal, 1e-6);
        expensive.expensive = true;
        let (rejected, _) = q.submit(expensive, 2, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::Overload);
        // the high lane rides through the overload
        let mut high = Ticket::new(Priority::High, 1e-6);
        high.expensive = true;
        q.submit(high, 3, 0.0).unwrap();
    }

    #[test]
    fn high_lane_deadline_ignores_normal_backlog_it_bypasses() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(
            QosConfig {
                queue_capacity: 64,
                watermark_s: 0.0,
                default_deadline: Some(Duration::from_millis(100)),
            },
            1,
        );
        // 1s of normal-lane backlog would sink any normal-lane deadline...
        q.submit(Ticket::new(Priority::Normal, 1.0), 1, 0.0).unwrap();
        let (rejected, _) = q.submit(Ticket::new(Priority::Normal, 1e-6), 2, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::DeadlineUnmeetable);
        // ...but a high request bypasses it and must be admitted
        q.submit(Ticket::new(Priority::High, 1e-6), 3, 0.0).unwrap();
        // high-lane backlog and downstream work still count against it
        q.submit(Ticket::new(Priority::High, 1.0), 4, 0.0).unwrap();
        let (rejected, _) = q.submit(Ticket::new(Priority::High, 1e-6), 5, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::DeadlineUnmeetable);
    }

    #[test]
    fn downstream_backlog_counts_against_deadline_and_watermark() {
        // the queue itself is empty, but 10ms of drained-but-unfinished work
        // sits in the pipeline: deadline and watermark must still see it
        let q: AdmissionQueue<u32> = AdmissionQueue::new(config(64, 1e-3), 1);
        let mut tight = Ticket::new(Priority::Normal, 1e-6);
        tight.deadline = Some(Duration::from_millis(5));
        let (rejected, _) = q.submit(tight, 1, 10e-3).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::DeadlineUnmeetable);
        assert!(rejected.est_wait >= Duration::from_millis(9));

        let mut expensive = Ticket::new(Priority::Normal, 1e-6);
        expensive.expensive = true;
        let (rejected, _) = q.submit(expensive, 2, 10e-3).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::Overload);

        // with no downstream backlog both are admitted
        let mut tight = Ticket::new(Priority::Normal, 1e-6);
        tight.deadline = Some(Duration::from_millis(5));
        q.submit(tight, 3, 0.0).unwrap();
        let mut expensive = Ticket::new(Priority::Normal, 1e-6);
        expensive.expensive = true;
        q.submit(expensive, 4, 0.0).unwrap();
    }

    #[test]
    fn close_returns_remaining_and_rejects_later_submits() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(config(8, 0.0), 1);
        q.submit(Ticket::new(Priority::Normal, 1e-6), 1, 0.0).unwrap();
        q.submit(Ticket::new(Priority::High, 1e-6), 2, 0.0).unwrap();
        let rest: Vec<u32> = q.close().into_iter().map(|(_, v)| v).collect();
        assert_eq!(rest, vec![2, 1], "drained in priority order");
        assert_eq!(q.total_depth(), 0);
        let (rejected, _) = q.submit(Ticket::new(Priority::Normal, 1e-6), 3, 0.0).unwrap_err();
        assert_eq!(rejected.reason, RejectReason::Shutdown);
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Closed));
    }

    #[test]
    fn producer_consumer_across_threads() {
        let q: Arc<AdmissionQueue<usize>> = Arc::new(AdmissionQueue::new(config(1024, 0.0), 2));
        let total = 200usize;
        std::thread::scope(|s| {
            for t in 0..4usize {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..total / 4 {
                        let pr = if i % 3 == 0 { Priority::High } else { Priority::Normal };
                        q.submit(Ticket::new(pr, 1e-6), t * 1000 + i, 0.0).unwrap();
                    }
                });
            }
            let q = q.clone();
            let consumer = s.spawn(move || {
                let mut got = 0usize;
                while got < total {
                    match q.pop_timeout(Duration::from_millis(100)) {
                        Pop::Item(_, _) => got += 1,
                        Pop::TimedOut => {}
                        Pop::Closed => break,
                    }
                }
                got
            });
            assert_eq!(consumer.join().unwrap(), total);
        });
        assert_eq!(q.total_depth(), 0);
    }
}
