//! Cost-aware load shedding — the pure admission rule.
//!
//! The planner's per-matrix predicted execution time (cuTeSpMM's synergy
//! model: high-synergy matrices are cheap on the TCU path, low-synergy ones
//! are expensive) turns admission into a cost decision rather than an
//! arrival-order one. When the total queued predicted work crosses a
//! watermark, new normal-priority work on expensive (low-synergy) matrices
//! is rejected first; past twice the watermark all normal-priority work is
//! shed. The high lane is only ever bounded by the hard capacity and its
//! own deadline.

use super::deadline;
use super::queue::{Priority, Ticket};
use std::fmt;
use std::time::Duration;

/// Why a request was shed at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue is at its hard capacity.
    QueueFull,
    /// Queued predicted work crossed the watermark and this request is in
    /// the shed class (normal priority; expensive matrices go first).
    Overload,
    /// The estimated wait already exceeds the request's deadline.
    DeadlineUnmeetable,
    /// The queue was drained for graceful shutdown.
    Shutdown,
}

impl RejectReason {
    pub const COUNT: usize = 4;

    pub fn index(self) -> usize {
        match self {
            RejectReason::QueueFull => 0,
            RejectReason::Overload => 1,
            RejectReason::DeadlineUnmeetable => 2,
            RejectReason::Shutdown => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "full",
            RejectReason::Overload => "overload",
            RejectReason::DeadlineUnmeetable => "deadline",
            RejectReason::Shutdown => "shutdown",
        }
    }

    pub fn all() -> [RejectReason; RejectReason::COUNT] {
        [
            RejectReason::QueueFull,
            RejectReason::Overload,
            RejectReason::DeadlineUnmeetable,
            RejectReason::Shutdown,
        ]
    }
}

/// Typed admission rejection: the caller learns why the request was shed
/// and how long the queue would have made it wait.
#[derive(Clone, Copy, Debug)]
pub struct Rejected {
    pub reason: RejectReason,
    /// Estimated queue wait at the moment of rejection.
    pub est_wait: Duration,
    pub priority: Priority,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rejected ({}, {} lane, est_wait={:.1}ms)",
            self.reason.name(),
            self.priority.name(),
            self.est_wait.as_secs_f64() * 1e3
        )
    }
}

/// Admission policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ShedPolicy {
    /// Hard bound on queued requests (mirrors the queue's own bound so the
    /// verdict can be computed from a snapshot of the queue state).
    pub capacity: usize,
    /// Watermark on total queued predicted work (seconds). `0.0` disables
    /// overload shedding (only the hard bound and deadlines apply).
    pub watermark_s: f64,
}

/// The pure admission rule over a snapshot of the queue state. Checks run
/// hard-bound first, then deadline, then the cost watermark, so a rejection
/// reason always names the tightest violated constraint.
pub fn admit(
    policy: &ShedPolicy,
    depth: usize,
    queued_cost_s: f64,
    ticket: &Ticket,
    est_wait: Duration,
) -> Result<(), RejectReason> {
    if depth >= policy.capacity {
        return Err(RejectReason::QueueFull);
    }
    if deadline::unmeetable(est_wait, ticket.deadline) {
        return Err(RejectReason::DeadlineUnmeetable);
    }
    let over = queued_cost_s > 2.0 * policy.watermark_s
        || (queued_cost_s > policy.watermark_s && ticket.expensive);
    if ticket.priority == Priority::Normal && policy.watermark_s > 0.0 && over {
        return Err(RejectReason::Overload);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::queue::BoundedDualQueue;

    fn ticket(p: Priority, expensive: bool, deadline: Option<Duration>) -> Ticket {
        let mut t = Ticket::new(p, 100e-6);
        t.expensive = expensive;
        t.deadline = deadline;
        t
    }

    #[test]
    fn hard_bound_rejects_every_lane() {
        let p = ShedPolicy { capacity: 4, watermark_s: 0.0 };
        for pr in Priority::all() {
            let t = ticket(pr, false, None);
            assert_eq!(admit(&p, 4, 0.0, &t, Duration::ZERO), Err(RejectReason::QueueFull));
            assert_eq!(admit(&p, 3, 0.0, &t, Duration::ZERO), Ok(()));
        }
    }

    #[test]
    fn deadline_shed_beats_waiting_to_time_out() {
        let p = ShedPolicy { capacity: 100, watermark_s: 0.0 };
        let t = ticket(Priority::High, false, Some(Duration::from_millis(5)));
        assert_eq!(admit(&p, 0, 0.0, &t, Duration::from_millis(4)), Ok(()));
        assert_eq!(
            admit(&p, 0, 0.0, &t, Duration::from_millis(6)),
            Err(RejectReason::DeadlineUnmeetable)
        );
        // no deadline -> no deadline shed, however long the wait
        let t = ticket(Priority::Normal, false, None);
        assert_eq!(admit(&p, 0, 0.0, &t, Duration::from_secs(60)), Ok(()));
    }

    #[test]
    fn watermark_sheds_expensive_normal_work_first() {
        let p = ShedPolicy { capacity: 1000, watermark_s: 1e-3 };
        let over_soft = 1.5e-3; // between watermark and 2x watermark
        let over_hard = 2.5e-3;

        // below the watermark everything is admitted
        for (pr, exp) in [(Priority::Normal, true), (Priority::Normal, false)] {
            assert_eq!(admit(&p, 1, 0.5e-3, &ticket(pr, exp, None), Duration::ZERO), Ok(()));
        }
        // soft watermark: only normal+expensive is shed
        assert_eq!(
            admit(&p, 1, over_soft, &ticket(Priority::Normal, true, None), Duration::ZERO),
            Err(RejectReason::Overload)
        );
        assert_eq!(
            admit(&p, 1, over_soft, &ticket(Priority::Normal, false, None), Duration::ZERO),
            Ok(())
        );
        // hard watermark: all normal work is shed
        assert_eq!(
            admit(&p, 1, over_hard, &ticket(Priority::Normal, false, None), Duration::ZERO),
            Err(RejectReason::Overload)
        );
        // the high lane is never overload-shed
        for cost in [over_soft, over_hard] {
            assert_eq!(admit(&p, 1, cost, &ticket(Priority::High, true, None), Duration::ZERO), Ok(()));
        }
    }

    #[test]
    fn zero_watermark_disables_overload_shedding() {
        let p = ShedPolicy { capacity: 1000, watermark_s: 0.0 };
        let t = ticket(Priority::Normal, true, None);
        assert_eq!(admit(&p, 10, 100.0, &t, Duration::ZERO), Ok(()));
    }

    #[test]
    fn rejected_displays_reason_lane_and_wait() {
        let r = Rejected {
            reason: RejectReason::Overload,
            est_wait: Duration::from_millis(12),
            priority: Priority::Normal,
        };
        let s = r.to_string();
        assert!(s.starts_with("rejected"), "{s}");
        assert!(s.contains("overload"), "{s}");
        assert!(s.contains("normal"), "{s}");
        assert!(s.contains("12.0ms"), "{s}");
    }

    #[test]
    fn reason_indices_cover_all() {
        let mut seen = [false; RejectReason::COUNT];
        for r in RejectReason::all() {
            seen[r.index()] = true;
            assert!(!r.name().is_empty());
        }
        assert!(seen.iter().all(|&s| s));
    }

    /// Deterministic saturation: a steady overload against the admission
    /// rule must engage the cost watermark long before the hard capacity
    /// bound — shed-before-overflow.
    #[test]
    fn saturation_sheds_before_overflow() {
        let policy = ShedPolicy { capacity: 1000, watermark_s: 1e-3 };
        let mut q: BoundedDualQueue<usize> = BoundedDualQueue::new(policy.capacity);
        let mut overload = 0usize;
        let mut full = 0usize;
        let mut max_depth = 0usize;
        for i in 0..5000usize {
            let t = ticket(Priority::Normal, true, None);
            let est = super::super::deadline::estimate_wait(q.queued_cost_s(), 1);
            match admit(&policy, q.depth(), q.queued_cost_s(), &t, est) {
                Ok(()) => q.push(t, i).unwrap(),
                Err(RejectReason::Overload) => overload += 1,
                Err(RejectReason::QueueFull) => full += 1,
                Err(_) => {}
            }
            if i % 3 == 0 {
                let _ = q.pop(); // drain slower than arrivals
            }
            max_depth = max_depth.max(q.depth());
        }
        assert!(overload > 0, "watermark shedding never engaged");
        assert_eq!(full, 0, "hard bound hit before cost-aware shedding");
        assert!(max_depth < policy.capacity, "depth {max_depth} reached the hard bound");
    }
}
