//! Matrix registry — the preprocess-once cache behind the serving layer.
//!
//! §6.3's amortization argument is operationalized here: HRPB construction
//! (and engine preparation) happens exactly once per registered matrix, then
//! hundreds-to-thousands of SpMM requests reuse it.

use crate::formats::Coo;
use crate::hrpb::{self, Hrpb, HrpbStats};
use crate::planner::{Plan, Planner};
use crate::spmm::hrpb::HrpbEngine;
use crate::spmm::{Algo, SpmmEngine};
use crate::synergy::{self, Synergy};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Opaque handle to a registered matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatrixId(pub u64);

/// Everything cached for one matrix.
pub struct Entry {
    pub id: MatrixId,
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub hrpb: Arc<Hrpb>,
    /// The native HRPB engine. `None` only for planned entries routed to a
    /// scalar engine — building it there would deep-clone the HRPB for an
    /// engine that never executes (fixed policies always carry it).
    pub engine: Option<Arc<HrpbEngine>>,
    pub stats: HrpbStats,
    pub synergy: Synergy,
    /// Wall-clock preprocessing cost (the §6.3 overhead; under planned
    /// registration this includes planning plus the chosen engine's
    /// preparation).
    pub preprocess_time: Duration,
    /// The planner's decision for this matrix (`None` under fixed policies).
    pub plan: Option<Arc<Plan>>,
    /// Predicted execution cost per fused B column (seconds) — the QoS
    /// admission layer's cost signal. Planned entries reuse the plan's
    /// prediction; unplanned entries fall back to the analytical A100 model
    /// for the HRPB engine.
    pub cost_s_per_col: f64,
    /// Engine that executes batches under `EnginePolicy::Auto`: the planned
    /// engine, or the HRPB engine when registration was unplanned.
    pub exec: Arc<dyn SpmmEngine>,
}

/// Thread-safe preprocess-once registry.
#[derive(Default)]
pub struct Registry {
    entries: RwLock<HashMap<MatrixId, Arc<Entry>>>,
    by_name: RwLock<HashMap<String, MatrixId>>,
    next: std::sync::atomic::AtomicU64,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a matrix: builds HRPB + engine once, returns the handle.
    /// Re-registering the same name returns the existing entry.
    pub fn register(&self, name: &str, coo: &Coo) -> MatrixId {
        self.register_inner(name, coo, None)
    }

    /// Register with per-matrix engine planning (`EnginePolicy::Auto`): the
    /// planner ranks every candidate engine off the (already built) HRPB
    /// stats and the entry carries the chosen engine, prepared once. Repeat
    /// registrations of a structurally identical matrix hit the plan cache.
    pub fn register_planned(&self, name: &str, coo: &Coo, planner: &Planner) -> MatrixId {
        self.register_inner(name, coo, Some(planner))
    }

    fn register_inner(&self, name: &str, coo: &Coo, planner: Option<&Planner>) -> MatrixId {
        if let Some(&id) = self.by_name.read().unwrap().get(name) {
            return id;
        }
        let t0 = std::time::Instant::now();
        let hrpb = Arc::new(hrpb::build_from_coo(coo));
        let stats = hrpb::stats::compute(&hrpb);
        let plan = planner.map(|p| p.plan_with_hrpb(coo, &hrpb));
        let (engine, exec): (Option<Arc<HrpbEngine>>, Arc<dyn SpmmEngine>) = match &plan {
            Some(plan) if plan.engine != Algo::Hrpb => {
                (None, Arc::from(plan.engine.prepare(coo)))
            }
            _ => {
                let e = Arc::new(HrpbEngine::from_hrpb((*hrpb).clone()));
                (Some(e.clone()), e)
            }
        };
        let cost_s_per_col = match &plan {
            Some(p) => p.predicted_s_per_col,
            None => {
                // cheap HRPB-only profile: prices the matrix for QoS
                // admission without the full engine-ranking profile pass
                let profile = crate::gpumodel::MatrixProfile::hrpb_only(
                    coo.rows,
                    coo.cols,
                    coo.nnz(),
                    stats,
                    &hrpb,
                );
                let width = 128usize;
                let pred = crate::gpumodel::algos::predict(
                    Algo::Hrpb,
                    &profile,
                    width,
                    &crate::gpumodel::Machine::a100(),
                );
                pred.time_s / width as f64
            }
        };
        let preprocess_time = t0.elapsed();
        let id = MatrixId(self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed));
        let entry = Arc::new(Entry {
            id,
            name: name.to_string(),
            rows: coo.rows,
            cols: coo.cols,
            nnz: coo.nnz(),
            hrpb,
            engine,
            stats,
            synergy: synergy::Synergy::from_alpha(stats.alpha),
            preprocess_time,
            plan,
            cost_s_per_col,
            exec,
        });
        self.entries.write().unwrap().insert(id, entry);
        self.by_name.write().unwrap().insert(name.to_string(), id);
        id
    }

    pub fn get(&self, id: MatrixId) -> Option<Arc<Entry>> {
        self.entries.read().unwrap().get(&id).cloned()
    }

    pub fn by_name(&self, name: &str) -> Option<Arc<Entry>> {
        let id = *self.by_name.read().unwrap().get(name)?;
        self.get(id)
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All entries (for reports), ordered by id.
    pub fn entries(&self) -> Vec<Arc<Entry>> {
        let mut v: Vec<_> = self.entries.read().unwrap().values().cloned().collect();
        v.sort_by_key(|e| e.id);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn register_once_reuse_after() {
        let reg = Registry::new();
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(1));
        let id1 = reg.register("m1", &coo);
        let id2 = reg.register("m1", &coo);
        assert_eq!(id1, id2);
        assert_eq!(reg.len(), 1);
        let e = reg.get(id1).unwrap();
        assert_eq!(e.nnz, coo.nnz());
        assert!(e.preprocess_time.as_nanos() > 0);
    }

    #[test]
    fn distinct_names_distinct_ids() {
        let reg = Registry::new();
        let mut rng = Rng::new(2);
        let a = Coo::random(32, 32, 0.2, &mut rng);
        let b = Coo::random(48, 48, 0.2, &mut rng);
        let ia = reg.register("a", &a);
        let ib = reg.register("b", &b);
        assert_ne!(ia, ib);
        assert_eq!(reg.by_name("b").unwrap().id, ib);
        assert_eq!(reg.entries().len(), 2);
    }

    #[test]
    fn unplanned_entries_execute_on_hrpb() {
        let reg = Registry::new();
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(3));
        let id = reg.register("m", &coo);
        let e = reg.get(id).unwrap();
        assert!(e.plan.is_none());
        assert!(e.engine.is_some());
        assert_eq!(e.exec.name(), "cutespmm");
    }

    #[test]
    fn planned_registration_carries_plan_and_engine() {
        use crate::gpumodel::Machine;
        let planner = Planner::new(Machine::a100());
        let reg = Registry::new();

        // low synergy: one nonzero per brick -> a scalar engine
        let lone: Vec<(usize, usize, f32)> = (0..64).map(|p| (p * 16, p * 16, 1.0)).collect();
        let low = Coo::from_triplets(1024, 1024, &lone);
        let low_id = reg.register_planned("low", &low, &planner);
        let e = reg.get(low_id).unwrap();
        let plan = e.plan.as_ref().unwrap();
        assert!(Algo::scalar_core().contains(&plan.engine), "{}", plan.rationale);
        assert_eq!(e.exec.name(), plan.engine.name());
        assert_eq!(e.exec.shape(), (1024, 1024));
        assert!(e.engine.is_none(), "scalar-routed entries skip the HRPB engine build");

        // structurally identical matrix under a new name: plan cache hit
        let hits_before = planner.cache().stats().hits;
        let low2_id = reg.register_planned("low-again", &low, &planner);
        assert_ne!(low_id, low2_id);
        assert_eq!(planner.cache().stats().hits, hits_before + 1);
    }

    #[test]
    fn entries_carry_positive_cost_estimates() {
        use crate::gpumodel::Machine;
        let reg = Registry::new();
        let coo = Coo::random(256, 256, 0.05, &mut Rng::new(9));
        let id = reg.register("unplanned", &coo);
        let e = reg.get(id).unwrap();
        assert!(
            e.cost_s_per_col.is_finite() && e.cost_s_per_col > 0.0,
            "cost {}",
            e.cost_s_per_col
        );

        // planned entries reuse the plan's per-column prediction exactly
        let planner = Planner::new(Machine::a100());
        let id2 = reg.register_planned("planned", &coo, &planner);
        let e2 = reg.get(id2).unwrap();
        let plan = e2.plan.as_ref().unwrap();
        assert_eq!(e2.cost_s_per_col, plan.predicted_s_per_col);
    }

    #[test]
    fn concurrent_registration_is_safe() {
        let reg = Arc::new(Registry::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let reg = reg.clone();
                s.spawn(move || {
                    let coo = Coo::random(64, 64, 0.1, &mut Rng::new(t));
                    reg.register(&format!("m{t}"), &coo);
                });
            }
        });
        assert_eq!(reg.len(), 4);
    }
}
