//! Matrix registry — the preprocess-once cache behind the serving layer.
//!
//! §6.3's amortization argument is operationalized here: HRPB construction
//! (and engine preparation) happens exactly once per registered matrix, then
//! hundreds-to-thousands of SpMM requests reuse it.
//!
//! Two layers extend "once" beyond a single registration:
//!
//! * **Once per process, even under races** — concurrent registrations of
//!   the same name hold a per-name reservation, so exactly one thread builds
//!   and every loser blocks briefly and reuses the winner's entry (same
//!   [`MatrixId`]).
//! * **Once per artifact directory, across restarts** — with an
//!   [`ArtifactStore`] attached ([`Registry::with_store`]), registration
//!   consults the store by structural fingerprint before building (warm
//!   start skips the whole HRPB build and planning pass) and persists the
//!   artifact after a cold build.

use super::breaker::Breaker;
use crate::formats::Coo;
use crate::hrpb::{self, ArtifactStore, Hrpb, HrpbStats};
use crate::planner::{fingerprint, Plan, Planner};
use crate::spmm::hrpb::HrpbEngine;
use crate::spmm::{Algo, SpmmEngine};
use crate::synergy::{self, Synergy};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

/// Opaque handle to a registered matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatrixId(pub u64);

/// Everything cached for one matrix.
pub struct Entry {
    pub id: MatrixId,
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub hrpb: Arc<Hrpb>,
    /// The native HRPB engine. `None` only for planned entries routed to a
    /// scalar engine — building it there would deep-clone the HRPB for an
    /// engine that never executes (fixed policies always carry it).
    pub engine: Option<Arc<HrpbEngine>>,
    pub stats: HrpbStats,
    pub synergy: Synergy,
    /// Wall-clock preprocessing cost (the §6.3 overhead; under planned
    /// registration this includes planning plus the chosen engine's
    /// preparation).
    pub preprocess_time: Duration,
    /// The planner's decision for this matrix (`None` under fixed policies).
    pub plan: Option<Arc<Plan>>,
    /// Row-reorder gains when this entry serves through a
    /// similarity-clustered permutation ([`crate::reorder`]): α/β
    /// before/after plus the one-time preprocessing seconds. Mirrored into
    /// the metrics report's `reorder=[...]` section. `None` = natural
    /// order (always, under fixed policies — activation is planner-gated).
    pub reorder: Option<crate::reorder::Gains>,
    /// Predicted execution cost per fused B column (seconds) — the QoS
    /// admission layer's cost signal. Planned entries reuse the plan's
    /// prediction; unplanned entries fall back to the analytical A100 model
    /// for the HRPB engine.
    pub cost_s_per_col: f64,
    /// Engine that executes batches under `EnginePolicy::Auto`: the planned
    /// engine, or the HRPB engine when registration was unplanned.
    pub exec: Arc<dyn SpmmEngine>,
    /// Scalar CSR engine the circuit breaker degrades to when the primary
    /// engine faults (reused directly when the plan already routed to CSR
    /// — re-preparing it would double the memory for nothing).
    pub fallback: Arc<dyn SpmmEngine>,
    /// Per-matrix circuit breaker ([`super::breaker`]): K consecutive
    /// contained faults reroute this matrix to `fallback`; faults on the
    /// fallback too quarantine it with a typed rejection.
    pub breaker: Arc<Breaker>,
}

/// A per-name registration reservation: the winner builds, losers wait on
/// the condvar. `done` is `None` while the build runs; `Some(None)` when the
/// builder unwound (waiters retry and one of them takes over);
/// `Some(Some(id))` when the entry is published.
#[derive(Default)]
struct Reservation {
    done: Mutex<Option<Option<MatrixId>>>,
    cv: Condvar,
}

/// Clears a reservation on scope exit — including unwinding, so a panicking
/// builder can never strand its waiters.
struct ReservationGuard<'a> {
    registry: &'a Registry,
    name: &'a str,
    reservation: Arc<Reservation>,
    id: Option<MatrixId>,
}

impl Drop for ReservationGuard<'_> {
    fn drop(&mut self) {
        self.registry.reservations.lock().unwrap().remove(self.name);
        *self.reservation.done.lock().unwrap() = Some(self.id);
        self.reservation.cv.notify_all();
    }
}

/// Thread-safe preprocess-once registry.
#[derive(Default)]
pub struct Registry {
    entries: RwLock<HashMap<MatrixId, Arc<Entry>>>,
    by_name: RwLock<HashMap<String, MatrixId>>,
    /// In-progress registrations by name (the check-then-act race fix).
    reservations: Mutex<HashMap<String, Arc<Reservation>>>,
    /// Persistent artifact store; `None` keeps the in-memory-only behavior.
    store: Option<Arc<ArtifactStore>>,
    next: std::sync::atomic::AtomicU64,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry that warm-starts from (and persists to) an on-disk
    /// artifact store.
    pub fn with_store(store: Arc<ArtifactStore>) -> Registry {
        Registry { store: Some(store), ..Registry::default() }
    }

    /// The attached artifact store, if any.
    pub fn store(&self) -> Option<&Arc<ArtifactStore>> {
        self.store.as_ref()
    }

    /// Register a matrix: builds HRPB + engine once, returns the handle.
    /// Re-registering the same name returns the existing entry.
    pub fn register(&self, name: &str, coo: &Coo) -> MatrixId {
        self.register_inner(name, coo, None)
    }

    /// Register with per-matrix engine planning (`EnginePolicy::Auto`): the
    /// planner ranks every candidate engine off the (already built) HRPB
    /// stats and the entry carries the chosen engine, prepared once. Repeat
    /// registrations of a structurally identical matrix hit the plan cache.
    pub fn register_planned(&self, name: &str, coo: &Coo, planner: &Planner) -> MatrixId {
        self.register_inner(name, coo, Some(planner))
    }

    fn register_inner(&self, name: &str, coo: &Coo, planner: Option<&Planner>) -> MatrixId {
        loop {
            if let Some(&id) = self.by_name.read().unwrap().get(name) {
                return id;
            }
            // take or join the per-name reservation: exactly one thread may
            // build a given name at a time
            let (reservation, owner) = {
                let mut map = self.reservations.lock().unwrap();
                // second chance under the reservation lock: a winner
                // publishes `by_name` before releasing its reservation, so
                // this re-check cannot miss a completed registration
                if let Some(&id) = self.by_name.read().unwrap().get(name) {
                    return id;
                }
                match map.get(name) {
                    Some(r) => (r.clone(), false),
                    None => {
                        let r = Arc::new(Reservation::default());
                        map.insert(name.to_string(), r.clone());
                        (r, true)
                    }
                }
            };
            if !owner {
                // loser: wait for the winner's id and reuse it
                let mut done = reservation.done.lock().unwrap();
                while done.is_none() {
                    done = reservation.cv.wait(done).unwrap();
                }
                match *done {
                    Some(Some(id)) => return id,
                    // the builder unwound; retry and take over the build
                    Some(None) | None => continue,
                }
            }
            let mut guard = ReservationGuard {
                registry: self,
                name,
                reservation: reservation.clone(),
                id: None,
            };
            let id = self.build_entry(name, coo, planner);
            guard.id = Some(id);
            return id;
        }
    }

    /// Build (or warm-load) and publish one entry. Caller holds the
    /// per-name reservation.
    fn build_entry(&self, name: &str, coo: &Coo, planner: Option<&Planner>) -> MatrixId {
        let t0 = std::time::Instant::now();
        let fp = fingerprint(coo);

        // warm start: a persisted artifact replaces the HRPB build, the
        // stats pass and (when present) the planning pass. The full-content
        // digest guards against fingerprint collisions: same sparsity
        // pattern with changed values must rebuild, never serve stale data.
        let digest = self
            .store
            .as_ref()
            .map(|_| hrpb::serialize::content_digest(coo))
            .unwrap_or(0);
        let loaded = self
            .store
            .as_ref()
            .and_then(|s| s.load_matching(fp, coo.rows, coo.cols, coo.nnz(), digest));
        let from_store = loaded.is_some();
        let (hrpb, stats, stored_plan, reorder_gains) = match loaded {
            Some(a) => {
                let stored = a.plan.map(Arc::new);
                // warm start: the permutation rides in on the artifact and
                // the gains (for reporting) on the stored plan
                let gains = stored.as_ref().and_then(|p| p.reorder);
                (Arc::new(a.hrpb), a.stats, stored, gains)
            }
            None => {
                let csr = crate::formats::Csr::from_coo(coo);
                let threads =
                    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
                // similarity-reorder pass ([`crate::reorder`]), planner-
                // gated: the proposal is priced exactly from signatures +
                // per-panel column unions BEFORE any build, so activation
                // never pays for two HRPB builds
                let mut gains = None;
                let mut perm = None;
                let mut geometry = crate::params::BrickGeometry::DEFAULT;
                if let Some(p) = planner {
                    let t_reorder = std::time::Instant::now();
                    let proposal =
                        crate::reorder::propose(&csr, crate::params::TM, crate::params::TK);
                    if p.gate_reorder(&proposal) {
                        gains = Some(proposal.gains(t_reorder.elapsed().as_secs_f64()));
                        perm = Some(proposal.perm);
                    }
                    // brick-geometry choice, also priced exactly BEFORE any
                    // build — under the row order that will actually be
                    // built, so the winner is built exactly once
                    let priced = crate::reorder::price_catalog(
                        &csr,
                        perm.as_ref(),
                        crate::params::TM,
                        crate::params::TK,
                    );
                    geometry = p.choose_geometry(&priced);
                }
                let hrpb = Arc::new(match perm {
                    Some(perm) => crate::reorder::build_reordered_geo(
                        &csr,
                        perm,
                        geometry,
                        crate::params::TM,
                        crate::params::TK,
                        threads,
                    ),
                    None => hrpb::builder::build_with_geometry_parallel(
                        &csr,
                        geometry,
                        crate::params::TM,
                        crate::params::TK,
                        threads,
                    ),
                });
                let stats = hrpb::stats::compute(&hrpb);
                // the built instance's exact numbers replace the estimate
                // (the pricer is exact, but keep the built stats authoritative)
                if let Some(g) = gains.as_mut() {
                    g.alpha_after = stats.alpha;
                    g.beta_after = stats.beta;
                }
                (hrpb, stats, None, gains)
            }
        };
        let plan = match (planner, stored_plan) {
            // the artifact's plan rides along only when it was evaluated at
            // this planner's width — otherwise engine choice and the QoS
            // cost signal would come from the wrong operating point — and
            // when it describes the geometry the artifact's HRPB is actually
            // built at. A mismatch re-plans off the loaded HRPB (no build).
            (Some(p), Some(stored))
                if stored.width == p.width() && stored.geometry == hrpb.geometry =>
            {
                // seed the planner's cache so repeat plans of the same
                // structure stay free
                p.seed_plan(stored.clone());
                Some(stored)
            }
            (Some(p), _) => {
                let mut profile = crate::gpumodel::MatrixProfile::with_hrpb(coo, &hrpb);
                profile.reorder = reorder_gains;
                Some(p.plan_assembled(fp, &profile))
            }
            (None, _) => None,
        };
        let (engine, exec): (Option<Arc<HrpbEngine>>, Arc<dyn SpmmEngine>) = match &plan {
            Some(plan) if plan.engine != Algo::Hrpb => {
                (None, Arc::from(plan.engine.prepare(coo)))
            }
            _ => {
                let mut native = HrpbEngine::from_shared_with_stats(hrpb.clone(), stats);
                // the planner's calibrated column-slab width (0 = auto);
                // round-trips through artifacts, so warm starts keep it
                if let Some(plan) = &plan {
                    native.set_slab_width(plan.slab_width);
                }
                let e = Arc::new(native);
                (Some(e.clone()), e)
            }
        };
        let cost_s_per_col = match &plan {
            Some(p) => p.predicted_s_per_col,
            None => {
                // cheap HRPB-only profile: prices the matrix for QoS
                // admission without the full engine-ranking profile pass
                let profile = crate::gpumodel::MatrixProfile::hrpb_only(
                    coo.rows,
                    coo.cols,
                    coo.nnz(),
                    stats,
                    &hrpb,
                );
                let width = 128usize;
                let pred = crate::gpumodel::algos::predict(
                    Algo::Hrpb,
                    &profile,
                    width,
                    &crate::gpumodel::Machine::a100(),
                );
                pred.time_s / width as f64
            }
        };
        // persist freshly built artifacts (best-effort: a read-only or full
        // disk must not fail registration)
        if let (Some(store), false) = (&self.store, from_store) {
            let _ = store.save(fp, &hrpb, &stats, digest, plan.as_deref());
        }
        let preprocess_time = t0.elapsed();
        // gains are attributed only when the HRPB engine actually serves
        // this entry (`engine` is Some exactly then) — a plan that routed
        // to a scalar engine executes the original COO, so reporting the
        // permutation as active would overstate the `reorder=[...]`
        // section. This registration's own measured gains win over gains
        // riding a cached/stored plan from an earlier structurally-
        // identical registration.
        let reorder = engine
            .is_some()
            .then(|| reorder_gains.or_else(|| plan.as_ref().and_then(|p| p.reorder)))
            .flatten();
        // the breaker's degraded path: always the scalar CSR engine, built
        // eagerly (a CSR build is cheap next to HRPB) so a fault can
        // degrade without a registration-sized pause on the serving path
        let fallback: Arc<dyn SpmmEngine> = if exec.name() == Algo::Csr.name() {
            exec.clone()
        } else {
            Arc::from(Algo::Csr.prepare(coo))
        };
        let id = MatrixId(self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed));
        let entry = Arc::new(Entry {
            id,
            name: name.to_string(),
            rows: coo.rows,
            cols: coo.cols,
            nnz: coo.nnz(),
            hrpb,
            engine,
            stats,
            synergy: synergy::Synergy::from_alpha(stats.alpha),
            preprocess_time,
            reorder,
            plan,
            cost_s_per_col,
            exec,
            fallback,
            breaker: Arc::new(Breaker::new()),
        });
        self.entries.write().unwrap().insert(id, entry);
        self.by_name.write().unwrap().insert(name.to_string(), id);
        id
    }

    pub fn get(&self, id: MatrixId) -> Option<Arc<Entry>> {
        self.entries.read().unwrap().get(&id).cloned()
    }

    pub fn by_name(&self, name: &str) -> Option<Arc<Entry>> {
        let id = *self.by_name.read().unwrap().get(name)?;
        self.get(id)
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All entries (for reports), ordered by id.
    pub fn entries(&self) -> Vec<Arc<Entry>> {
        let mut v: Vec<_> = self.entries.read().unwrap().values().cloned().collect();
        v.sort_by_key(|e| e.id);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn register_once_reuse_after() {
        let reg = Registry::new();
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(1));
        let id1 = reg.register("m1", &coo);
        let id2 = reg.register("m1", &coo);
        assert_eq!(id1, id2);
        assert_eq!(reg.len(), 1);
        let e = reg.get(id1).unwrap();
        assert_eq!(e.nnz, coo.nnz());
        assert!(e.preprocess_time.as_nanos() > 0);
    }

    #[test]
    fn distinct_names_distinct_ids() {
        let reg = Registry::new();
        let mut rng = Rng::new(2);
        let a = Coo::random(32, 32, 0.2, &mut rng);
        let b = Coo::random(48, 48, 0.2, &mut rng);
        let ia = reg.register("a", &a);
        let ib = reg.register("b", &b);
        assert_ne!(ia, ib);
        assert_eq!(reg.by_name("b").unwrap().id, ib);
        assert_eq!(reg.entries().len(), 2);
    }

    #[test]
    fn unplanned_entries_execute_on_hrpb() {
        let reg = Registry::new();
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(3));
        let id = reg.register("m", &coo);
        let e = reg.get(id).unwrap();
        assert!(e.plan.is_none());
        assert!(e.engine.is_some());
        assert_eq!(e.exec.name(), "cutespmm");
    }

    #[test]
    fn every_entry_carries_a_csr_fallback_and_a_closed_breaker() {
        use crate::formats::Dense;
        let reg = Registry::new();
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(4));
        let e = reg.get(reg.register("m", &coo)).unwrap();
        assert_eq!(e.fallback.name(), "csr");
        assert_eq!(e.fallback.shape(), (64, 64));
        assert_eq!(e.breaker.state(), super::super::BreakerState::Closed);
        // the fallback computes the same product as the primary engine
        let b = Dense::random(64, 8, &mut Rng::new(5));
        let want = coo.to_dense().matmul(&b);
        assert!(e.fallback.spmm(&b).rel_fro_error(&want) < 1e-5);

        // a plan that already routed to CSR reuses the exec engine
        // instead of preparing a second copy
        let planner = Planner::new(crate::gpumodel::Machine::a100());
        let lone: Vec<(usize, usize, f32)> = (0..64).map(|p| (p * 16, p * 16, 1.0)).collect();
        let low = Coo::from_triplets(1024, 1024, &lone);
        let e2 = reg.get(reg.register_planned("low", &low, &planner)).unwrap();
        if e2.exec.name() == "csr" {
            assert!(
                Arc::ptr_eq(&e2.exec, &e2.fallback),
                "CSR-routed entries must share one engine"
            );
        }
    }

    #[test]
    fn planned_registration_carries_plan_and_engine() {
        use crate::gpumodel::Machine;
        let planner = Planner::new(Machine::a100());
        let reg = Registry::new();

        // low synergy: one nonzero per brick -> a scalar engine
        let lone: Vec<(usize, usize, f32)> = (0..64).map(|p| (p * 16, p * 16, 1.0)).collect();
        let low = Coo::from_triplets(1024, 1024, &lone);
        let low_id = reg.register_planned("low", &low, &planner);
        let e = reg.get(low_id).unwrap();
        let plan = e.plan.as_ref().unwrap();
        assert!(Algo::scalar_core().contains(&plan.engine), "{}", plan.rationale);
        assert_eq!(e.exec.name(), plan.engine.name());
        assert_eq!(e.exec.shape(), (1024, 1024));
        assert!(e.engine.is_none(), "scalar-routed entries skip the HRPB engine build");

        // structurally identical matrix under a new name: plan cache hit
        let hits_before = planner.cache().stats().hits;
        let low2_id = reg.register_planned("low-again", &low, &planner);
        assert_ne!(low_id, low2_id);
        assert_eq!(planner.cache().stats().hits, hits_before + 1);
    }

    #[test]
    fn planned_registration_installs_the_slab_width_knob() {
        use crate::gpumodel::Machine;
        use crate::planner::Calibration;
        let planner = Planner::new(Machine::a100());
        let mut cal = Calibration::identity();
        cal.calibrated = true;
        cal.machine = "A100".into();
        cal.slab_width = 64;
        planner.set_calibration(cal);

        // high synergy (fully dense 16x16 blocks): the plan keeps the HRPB
        // engine, so the knob must land on the prepared engine
        let mut t = Vec::new();
        for p in 0..256usize {
            for r in 0..16 {
                for c in 0..16 {
                    t.push((p * 16 + r, (p % 4) * 16 + c, 1.0f32 + (r + c) as f32 * 0.01));
                }
            }
        }
        let coo = Coo::from_triplets(256 * 16, 64, &t);
        let reg = Registry::new();
        let id = reg.register_planned("high", &coo, &planner);
        let e = reg.get(id).unwrap();
        let plan = e.plan.as_ref().unwrap();
        assert_eq!(plan.engine, Algo::Hrpb, "{}", plan.rationale);
        assert_eq!(plan.slab_width, 64);
        assert_eq!(e.engine.as_ref().unwrap().slab_width(), 64);
    }

    #[test]
    fn entries_carry_positive_cost_estimates() {
        use crate::gpumodel::Machine;
        let reg = Registry::new();
        let coo = Coo::random(256, 256, 0.05, &mut Rng::new(9));
        let id = reg.register("unplanned", &coo);
        let e = reg.get(id).unwrap();
        assert!(
            e.cost_s_per_col.is_finite() && e.cost_s_per_col > 0.0,
            "cost {}",
            e.cost_s_per_col
        );

        // planned entries reuse the plan's per-column prediction exactly
        let planner = Planner::new(Machine::a100());
        let id2 = reg.register_planned("planned", &coo, &planner);
        let e2 = reg.get(id2).unwrap();
        let plan = e2.plan.as_ref().unwrap();
        assert_eq!(e2.cost_s_per_col, plan.predicted_s_per_col);
    }

    #[test]
    fn concurrent_registration_is_safe() {
        let reg = Arc::new(Registry::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let reg = reg.clone();
                s.spawn(move || {
                    let coo = Coo::random(64, 64, 0.1, &mut Rng::new(t));
                    reg.register(&format!("m{t}"), &coo);
                });
            }
        });
        assert_eq!(reg.len(), 4);
    }

    #[test]
    fn concurrent_same_name_registration_builds_once() {
        // the check-then-act regression: all racers must converge on ONE
        // entry with equal ids, not last-writer-wins duplicates
        let reg = Arc::new(Registry::new());
        let coo = Arc::new(Coo::random(256, 256, 0.05, &mut Rng::new(50)));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let ids: Vec<MatrixId> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let reg = reg.clone();
                    let coo = coo.clone();
                    let barrier = barrier.clone();
                    s.spawn(move || {
                        barrier.wait();
                        reg.register("shared", &coo)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(reg.len(), 1, "one name must produce exactly one entry");
        assert!(ids.windows(2).all(|w| w[0] == w[1]), "all racers share the winner's id: {ids:?}");
        assert_eq!(reg.by_name("shared").unwrap().id, ids[0]);
    }

    /// A structured matrix whose arrival row order hides the structure:
    /// dense 16-node block-diagonal units, rows shuffled.
    fn shuffled_blockdiag(rows: usize, seed: u64) -> Coo {
        let spec = crate::gen::MatrixSpec {
            name: "t".into(),
            rows,
            family: crate::gen::Family::BlockDiag { unit: 16, unit_density: 0.75 },
            seed,
        };
        let coo = spec.generate();
        crate::reorder::RowPermutation::random(coo.rows, &mut Rng::new(seed ^ 0x51))
            .apply_coo(&coo)
    }

    #[test]
    fn planned_registration_activates_reordering_and_serves_correctly() {
        use crate::gpumodel::Machine;
        let coo = shuffled_blockdiag(512, 70);
        let planner = Planner::new(Machine::a100());
        let reg = Registry::new();
        let id = reg.register_planned("scrambled", &coo, &planner);
        let e = reg.get(id).unwrap();

        // the gate must fire on recoverable structure, and the gains must
        // show a real α lift
        let gains = e.reorder.expect("reorder must activate on hidden block structure");
        assert!(
            gains.alpha_after > gains.alpha_before * 1.5,
            "α {} -> {}",
            gains.alpha_before,
            gains.alpha_after
        );
        assert_eq!(e.plan.as_ref().unwrap().reorder, Some(gains), "plan records the knob");
        assert!(e.hrpb.perm.is_some(), "the built HRPB carries the permutation");
        assert!((e.stats.alpha - gains.alpha_after).abs() < 1e-12);

        // served results come back in ORIGINAL row order
        let b = crate::formats::Dense::random(coo.cols, 16, &mut Rng::new(71));
        let want = coo.to_dense().matmul(&b);
        let got = e.exec.spmm(&b);
        assert!(got.rel_fro_error(&want) < 1e-5, "scatter epilogue restores row order");
    }

    #[test]
    fn planned_registration_picks_a_gainful_brick_geometry() {
        use crate::gpumodel::Machine;
        // scattered: one nonzero per row, all columns distinct within a
        // panel. The exact pricer predicts 2x less brick-MMA work at 8x1t
        // than at the default 16x4 (a lone nonzero fills 1/8 of its brick
        // instead of 1/64), so the chooser must deviate.
        let scattered: Vec<(usize, usize, f32)> =
            (0..512).map(|r| (r, (r * 37) % 512, 1.0 + r as f32 * 0.01)).collect();
        let coo = Coo::from_triplets(512, 512, &scattered);
        let planner = Planner::new(Machine::a100());
        let reg = Registry::new();
        let id = reg.register_planned("scattered", &coo, &planner);
        let e = reg.get(id).unwrap();
        let chosen = e.hrpb.geometry;
        assert!(!chosen.is_default(), "pricer predicts a 2x win; chose {chosen}");
        assert_eq!(e.plan.as_ref().unwrap().geometry, chosen, "plan records the shape");
        // serving at the chosen shape stays exact
        let b = crate::formats::Dense::random(coo.cols, 8, &mut Rng::new(80));
        let want = coo.to_dense().matmul(&b);
        assert!(e.exec.spmm(&b).rel_fro_error(&want) < 1e-5);

        // full dense 16x16 blocks: every catalog shape prices identical
        // brick-MMA work -> the chooser must never leave the default
        let mut t = Vec::new();
        for p in 0..32usize {
            for r in 0..16 {
                for c in 0..16 {
                    t.push((p * 16 + r, (p % 4) * 16 + c, 1.0f32));
                }
            }
        }
        let dense = Coo::from_triplets(32 * 16, 64, &t);
        let id2 = reg.register_planned("denseblocks", &dense, &planner);
        let e2 = reg.get(id2).unwrap();
        assert!(e2.hrpb.geometry.is_default(), "no predicted gain must stay default");
        assert!(e2.plan.as_ref().unwrap().geometry.is_default());

        // unplanned registration never deviates: geometry choice is
        // planner-gated exactly like reordering
        let id3 = reg.register("scattered-unplanned", &coo);
        assert!(reg.get(id3).unwrap().hrpb.geometry.is_default());
    }

    #[test]
    fn unplanned_registration_never_reorders() {
        let reg = Registry::new();
        let coo = shuffled_blockdiag(512, 72);
        let id = reg.register("plain", &coo);
        let e = reg.get(id).unwrap();
        assert!(e.reorder.is_none(), "activation is planner-gated");
        assert!(e.hrpb.perm.is_none());
    }

    fn tmp_store(tag: &str) -> Arc<crate::hrpb::ArtifactStore> {
        let dir = crate::hrpb::store::test_dir(&format!("registry_{tag}"));
        Arc::new(crate::hrpb::ArtifactStore::open(dir).unwrap())
    }

    #[test]
    fn warm_start_skips_rebuild_across_registries() {
        let store = tmp_store("warm");
        let coo = Coo::random(512, 512, 0.03, &mut Rng::new(51));

        // cold: process 1 builds and persists
        let reg1 = Registry::with_store(store.clone());
        let id1 = reg1.register("m", &coo);
        let cold = reg1.get(id1).unwrap();
        assert_eq!(store.stats().misses, 1);
        assert_eq!(store.stats().hits, 0);
        assert!(store.contains(crate::planner::fingerprint(&coo)));

        // warm: a fresh registry (a restarted process) loads the artifact
        let reg2 = Registry::with_store(store.clone());
        let id2 = reg2.register("m", &coo);
        let warm = reg2.get(id2).unwrap();
        assert_eq!(store.stats().hits, 1);
        assert_eq!(warm.nnz, cold.nnz);
        assert_eq!(warm.stats, cold.stats);
        assert_eq!(warm.hrpb.packed, cold.hrpb.packed, "artifact roundtrip is byte-identical");
        warm.hrpb.validate().unwrap();
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn warm_start_restores_the_plan() {
        use crate::gpumodel::Machine;
        let store = tmp_store("plan");
        let coo = Coo::random(256, 256, 0.08, &mut Rng::new(52));

        let planner1 = Planner::new(Machine::a100());
        let reg1 = Registry::with_store(store.clone());
        let id1 = reg1.register_planned("m", &coo, &planner1);
        let cold_plan = reg1.get(id1).unwrap().plan.clone().unwrap();

        let planner2 = Planner::new(Machine::a100());
        let reg2 = Registry::with_store(store.clone());
        let id2 = reg2.register_planned("m", &coo, &planner2);
        let warm = reg2.get(id2).unwrap();
        let warm_plan = warm.plan.clone().unwrap();
        assert_eq!(store.stats().hits, 1);
        assert_eq!(warm_plan.engine, cold_plan.engine);
        assert_eq!(warm_plan.predicted_s_per_col, cold_plan.predicted_s_per_col);
        assert_eq!(warm_plan.fingerprint, cold_plan.fingerprint);
        assert_eq!(warm.cost_s_per_col, warm_plan.predicted_s_per_col);
        // the restored plan seeds planner2's cache: planning the same
        // structure again is a cache hit, not a ranking pass
        let hits_before = planner2.cache().stats().hits;
        let cached = planner2.plan(&coo);
        assert_eq!(planner2.cache().stats().hits, hits_before + 1, "seeded plan must be cached");
        assert_eq!(cached.engine, warm_plan.engine);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn warm_start_restores_the_permutation_and_gains() {
        use crate::gpumodel::Machine;
        let store = tmp_store("reorder");
        let coo = shuffled_blockdiag(512, 73);

        // cold: activation builds the reordered HRPB and persists it
        let planner1 = Planner::new(Machine::a100());
        let reg1 = Registry::with_store(store.clone());
        let id1 = reg1.register_planned("m", &coo, &planner1);
        let cold = reg1.get(id1).unwrap();
        let cold_gains = cold.reorder.expect("cold registration must activate");
        let cold_perm = cold.hrpb.perm.clone().expect("permutation attached");

        // warm: a restarted process loads permutation + gains from disk
        let planner2 = Planner::new(Machine::a100());
        let reg2 = Registry::with_store(store.clone());
        let id2 = reg2.register_planned("m", &coo, &planner2);
        let warm = reg2.get(id2).unwrap();
        assert_eq!(store.stats().hits, 1, "warm start must hit the artifact");
        assert_eq!(
            warm.hrpb.perm.as_deref(),
            Some(cold_perm.as_ref()),
            "the permutation survives the restart byte-identically"
        );
        assert_eq!(warm.hrpb.packed, cold.hrpb.packed);
        assert_eq!(warm.reorder, Some(cold_gains), "gains ride the stored plan");

        // warm serving still lands in original row order
        let b = crate::formats::Dense::random(coo.cols, 8, &mut Rng::new(74));
        let want = coo.to_dense().matmul(&b);
        assert!(warm.exec.spmm(&b).rel_fro_error(&want) < 1e-5);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn changed_values_rebuild_instead_of_serving_stale_artifact() {
        // same sparsity pattern, one value changed at a non-sampled index:
        // the fingerprint (the artifact key) collides, so only the content
        // digest stands between the registry and silently-wrong results
        let store = tmp_store("stale");
        let coo = Coo::random(128, 128, 0.1, &mut Rng::new(54));
        assert!(coo.nnz() >= 1024, "test needs a sampling stride > 1");

        let reg1 = Registry::with_store(store.clone());
        reg1.register("m", &coo);

        let mut changed = coo.clone();
        changed.values[1] += 1.0;
        assert_eq!(
            crate::planner::fingerprint(&changed),
            crate::planner::fingerprint(&coo),
            "premise: the key collides"
        );
        let reg2 = Registry::with_store(store.clone());
        let id = reg2.register("m", &changed);
        let e = reg2.get(id).unwrap();
        assert_eq!(store.stats().invalidated, 1, "stale artifact must be invalidated");
        // the entry must carry the NEW values
        assert_eq!(
            crate::hrpb::decode::to_dense(&e.hrpb).max_abs_diff(&changed.to_dense()),
            0.0,
            "registry must serve the updated values, not the stale artifact"
        );
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn corrupt_artifact_triggers_rebuild_not_crash() {
        let store = tmp_store("corrupt");
        let coo = Coo::random(128, 128, 0.1, &mut Rng::new(53));
        let fp = crate::planner::fingerprint(&coo);

        let reg1 = Registry::with_store(store.clone());
        reg1.register("m", &coo);
        // corrupt the artifact on disk
        let path = store.path_for(fp);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xA5;
        std::fs::write(&path, &bytes).unwrap();

        let reg2 = Registry::with_store(store.clone());
        let id = reg2.register("m", &coo);
        let e = reg2.get(id).unwrap();
        e.hrpb.validate().unwrap();
        assert_eq!(store.stats().invalidated, 1);
        // the rebuild re-persisted a good artifact
        let reg3 = Registry::with_store(store.clone());
        reg3.register("m", &coo);
        assert_eq!(store.stats().hits, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
