//! Per-matrix circuit breaker — contain faults, degrade, probe, recover.
//!
//! The breaker sits between the batcher and the engines: every batch asks
//! it for a route before dispatch and reports the outcome after. The
//! state machine is the classic three-state breaker plus a terminal
//! quarantine for matrices that fault even on the scalar fallback:
//!
//! ```text
//!             K consecutive faults                probe succeeds
//!   Closed ─────────────────────────▶ Open ──▶ HalfOpen ──▶ Closed
//!     ▲                                ▲           │
//!     └── any primary success          └───────────┘ probe faults
//!         resets the count
//!   Open: requests serve on the CSR fallback; every PROBE_INTERVAL-th
//!         batch is routed back to the primary engine as a probe.
//!   Open + K consecutive fallback faults ──▶ Quarantined (terminal:
//!         requests get a typed rejection until re-registration).
//! ```
//!
//! All transitions happen under one small mutex per matrix — the lock is
//! taken twice per *batch*, not per request, so the cost is noise next to
//! an SpMM dispatch.

use std::sync::Mutex;

/// K — consecutive faults that open the breaker (and, on the fallback
/// path, quarantine the matrix).
pub const FAULT_THRESHOLD: u32 = 3;

/// While open, every n-th batch is routed to the primary engine as a
/// half-open probe.
pub const PROBE_INTERVAL: u64 = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests serve on the primary (planned) engine.
    Closed,
    /// Tripped: requests serve on the scalar CSR fallback.
    Open,
    /// A probe is in flight on the primary engine; everything else still
    /// serves on the fallback.
    HalfOpen,
    /// Faulted even on the fallback — terminal until re-registration.
    Quarantined,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Quarantined => "quarantined",
        }
    }
}

/// Where the breaker routed a batch. The worker passes the same value
/// back into [`Breaker::record_success`] / [`Breaker::record_fault`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Serve on the planned engine (breaker closed).
    Primary,
    /// Serve on the planned engine as a half-open probe.
    Probe,
    /// Serve on the scalar CSR fallback (breaker open).
    Fallback,
    /// Reject with a typed quarantine error.
    Reject,
}

/// Counter snapshot for metrics and reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BreakerCounters {
    pub opens: u64,
    pub closes: u64,
    pub probes: u64,
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    /// Consecutive primary-path faults (resets on any primary success).
    primary_faults: u32,
    /// Consecutive fallback faults while open (resets on fallback
    /// success) — K of these quarantine the matrix.
    fallback_faults: u32,
    /// Batches routed since the breaker opened — drives probe cadence.
    since_open: u64,
    counters: BreakerCounters,
}

/// One matrix's breaker. Shared behind `Arc` from the registry entry.
#[derive(Debug)]
pub struct Breaker {
    inner: Mutex<Inner>,
}

impl Default for Breaker {
    fn default() -> Breaker {
        Breaker::new()
    }
}

impl Breaker {
    pub fn new() -> Breaker {
        Breaker {
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                primary_faults: 0,
                fallback_faults: 0,
                since_open: 0,
                counters: BreakerCounters::default(),
            }),
        }
    }

    /// Route the next batch. Open breakers emit a [`Route::Probe`] every
    /// [`PROBE_INTERVAL`]-th batch and move to half-open until its
    /// outcome is reported.
    pub fn route(&self) -> Route {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match g.state {
            BreakerState::Closed => Route::Primary,
            BreakerState::Quarantined => Route::Reject,
            BreakerState::HalfOpen => Route::Fallback,
            BreakerState::Open => {
                g.since_open += 1;
                if g.since_open % PROBE_INTERVAL == 0 {
                    g.state = BreakerState::HalfOpen;
                    g.counters.probes += 1;
                    Route::Probe
                } else {
                    Route::Fallback
                }
            }
        }
    }

    /// Report a batch served without fault on `route`.
    pub fn record_success(&self, route: Route) {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match route {
            Route::Primary => g.primary_faults = 0,
            Route::Probe => {
                // the primary engine is healthy again
                g.state = BreakerState::Closed;
                g.counters.closes += 1;
                g.primary_faults = 0;
                g.fallback_faults = 0;
                g.since_open = 0;
            }
            Route::Fallback => g.fallback_faults = 0,
            Route::Reject => {}
        }
    }

    /// Report a contained fault on `route`. Returns the new state when
    /// this fault flipped the breaker (opened or quarantined), `None`
    /// otherwise — the caller mirrors transitions into metrics.
    pub fn record_fault(&self, route: Route) -> Option<BreakerState> {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match route {
            Route::Primary => {
                g.primary_faults += 1;
                if g.state == BreakerState::Closed && g.primary_faults >= FAULT_THRESHOLD {
                    g.state = BreakerState::Open;
                    g.counters.opens += 1;
                    g.since_open = 0;
                    g.fallback_faults = 0;
                    return Some(BreakerState::Open);
                }
                None
            }
            Route::Probe => {
                // the probe failed: back to open, next probe in a full interval
                g.state = BreakerState::Open;
                g.since_open = 0;
                None
            }
            Route::Fallback => {
                g.fallback_faults += 1;
                if g.fallback_faults >= FAULT_THRESHOLD {
                    g.state = BreakerState::Quarantined;
                    return Some(BreakerState::Quarantined);
                }
                None
            }
            Route::Reject => None,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).state
    }

    pub fn counters(&self) -> BreakerCounters {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fault_k_times(b: &Breaker, route: Route) -> Option<BreakerState> {
        let mut last = None;
        for _ in 0..FAULT_THRESHOLD {
            last = b.record_fault(route);
        }
        last
    }

    #[test]
    fn k_consecutive_faults_open_the_breaker() {
        let b = Breaker::new();
        assert_eq!(b.route(), Route::Primary);
        for i in 0..FAULT_THRESHOLD - 1 {
            assert_eq!(b.record_fault(Route::Primary), None, "fault {i} must not trip");
            assert_eq!(b.state(), BreakerState::Closed);
        }
        assert_eq!(b.record_fault(Route::Primary), Some(BreakerState::Open));
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.counters().opens, 1);
        assert_eq!(b.route(), Route::Fallback);
    }

    #[test]
    fn a_success_resets_the_consecutive_count() {
        let b = Breaker::new();
        for _ in 0..FAULT_THRESHOLD - 1 {
            b.record_fault(Route::Primary);
        }
        b.record_success(Route::Primary);
        for _ in 0..FAULT_THRESHOLD - 1 {
            assert_eq!(b.record_fault(Route::Primary), None);
        }
        assert_eq!(b.state(), BreakerState::Closed, "non-consecutive faults must not trip");
    }

    #[test]
    fn probe_cadence_and_a_successful_probe_closes() {
        let b = Breaker::new();
        fault_k_times(&b, Route::Primary);
        let mut probe_at = None;
        for i in 1..=PROBE_INTERVAL {
            match b.route() {
                Route::Fallback => {}
                Route::Probe => {
                    probe_at = Some(i);
                    break;
                }
                r => panic!("unexpected route {r:?}"),
            }
        }
        assert_eq!(probe_at, Some(PROBE_INTERVAL), "probe on the interval-th batch");
        // while the probe is in flight, other batches stay on the fallback
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.route(), Route::Fallback);
        b.record_success(Route::Probe);
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.counters().closes, 1);
        assert_eq!(b.counters().probes, 1);
        assert_eq!(b.route(), Route::Primary);
    }

    #[test]
    fn a_faulting_probe_reopens_for_a_full_interval() {
        let b = Breaker::new();
        fault_k_times(&b, Route::Primary);
        for _ in 0..PROBE_INTERVAL - 1 {
            assert_eq!(b.route(), Route::Fallback);
        }
        assert_eq!(b.route(), Route::Probe);
        b.record_fault(Route::Probe);
        assert_eq!(b.state(), BreakerState::Open);
        // the next probe is a full interval away again
        for _ in 0..PROBE_INTERVAL - 1 {
            assert_eq!(b.route(), Route::Fallback);
        }
        assert_eq!(b.route(), Route::Probe);
    }

    #[test]
    fn fallback_faults_quarantine_and_rejections_are_sticky() {
        let b = Breaker::new();
        fault_k_times(&b, Route::Primary);
        // fallback successes keep it serving
        b.record_success(Route::Fallback);
        for _ in 0..FAULT_THRESHOLD - 1 {
            assert_eq!(b.record_fault(Route::Fallback), None);
        }
        // a success resets the fallback count too
        b.record_success(Route::Fallback);
        assert_eq!(fault_k_times(&b, Route::Fallback), Some(BreakerState::Quarantined));
        assert_eq!(b.state(), BreakerState::Quarantined);
        for _ in 0..4 {
            assert_eq!(b.route(), Route::Reject, "quarantine is terminal");
        }
        // reporting against a rejected route is a no-op
        b.record_success(Route::Reject);
        b.record_fault(Route::Reject);
        assert_eq!(b.state(), BreakerState::Quarantined);
    }

    #[test]
    fn state_names_are_stable() {
        assert_eq!(BreakerState::Closed.name(), "closed");
        assert_eq!(BreakerState::Open.name(), "open");
        assert_eq!(BreakerState::HalfOpen.name(), "half-open");
        assert_eq!(BreakerState::Quarantined.name(), "quarantined");
    }
}
