//! Dynamic batching policy (pure logic, thread-free, unit-testable).
//!
//! SpMM requests against the *same matrix* compose: their B operands
//! concatenate along the feature dimension, one kernel launch serves the
//! whole group, and the C result slices back apart. This is the serving-side
//! analogue of the paper's observation that wider N amortizes the A-side
//! decode (Tables 3/4 trend) — the batcher manufactures wider N from
//! concurrent traffic.
//!
//! Policy: accumulate per-matrix groups; flush a group when its total
//! feature width reaches `max_batch_cols`, when it holds `max_batch_reqs`
//! requests, or when its oldest request has waited `max_delay`.

use crate::coordinator::registry::MatrixId;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush when a group's concatenated width reaches this many columns.
    pub max_batch_cols: usize,
    /// Flush when a group holds this many requests.
    pub max_batch_reqs: usize,
    /// Flush when the oldest request in a group is this old.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch_cols: 128, // one PJRT bucket width / the paper's N=128
            max_batch_reqs: 32,
            max_delay: Duration::from_millis(2),
        }
    }
}

/// An item awaiting batching: request `token` wants `cols` feature columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pending {
    pub token: u64,
    pub matrix: MatrixId,
    pub cols: usize,
}

/// A flushed batch: requests to fuse into one kernel launch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch {
    pub matrix: MatrixId,
    pub tokens: Vec<u64>,
    pub total_cols: usize,
}

struct Group {
    items: Vec<Pending>,
    cols: usize,
    oldest: Instant,
}

/// The batcher state machine.
pub struct Batcher {
    policy: BatchPolicy,
    groups: Vec<(MatrixId, Group)>, // small N of matrices: linear scan
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, groups: Vec::new() }
    }

    /// Number of requests currently held.
    pub fn pending(&self) -> usize {
        self.groups.iter().map(|(_, g)| g.items.len()).sum()
    }

    /// Add a request; returns a batch if this addition triggered a flush.
    pub fn push(&mut self, item: Pending, now: Instant) -> Option<Batch> {
        // oversized single request: flush it alone immediately
        if item.cols >= self.policy.max_batch_cols {
            return Some(Batch {
                matrix: item.matrix,
                tokens: vec![item.token],
                total_cols: item.cols,
            });
        }
        let idx = match self.groups.iter().position(|(m, _)| *m == item.matrix) {
            Some(i) => i,
            None => {
                self.groups.push((
                    item.matrix,
                    Group { items: Vec::new(), cols: 0, oldest: now },
                ));
                self.groups.len() - 1
            }
        };
        let g = &mut self.groups[idx].1;
        if g.items.is_empty() {
            g.oldest = now;
        }
        g.items.push(item);
        g.cols += item.cols;
        if g.cols >= self.policy.max_batch_cols || g.items.len() >= self.policy.max_batch_reqs {
            return Some(self.flush_index(idx));
        }
        None
    }

    /// Flush any group whose oldest member exceeded the delay budget.
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.groups.len() {
            if !self.groups[i].1.items.is_empty()
                && now.duration_since(self.groups[i].1.oldest) >= self.policy.max_delay
            {
                out.push(self.flush_index(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Flush every pending group (shutdown). Nothing is dropped on the
    /// floor: the coordinator either executes the returned groups (legacy
    /// ingress) or fails each held request cleanly with a typed rejection
    /// (QoS ingress, see `coordinator::qos_router_loop`).
    pub fn drain(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(i) = self.groups.iter().position(|(_, g)| !g.items.is_empty()) {
            out.push(self.flush_index(i));
        }
        self.groups.clear();
        out
    }

    /// Deadline of the earliest pending group (when `poll` next matters).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .iter()
            .filter(|(_, g)| !g.items.is_empty())
            .map(|(_, g)| g.oldest + self.policy.max_delay)
            .min()
    }

    fn flush_index(&mut self, idx: usize) -> Batch {
        let (matrix, g) = self.groups.swap_remove(idx);
        Batch {
            matrix,
            tokens: g.items.iter().map(|p| p.token).collect(),
            total_cols: g.cols,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(token: u64, matrix: u64, cols: usize) -> Pending {
        Pending { token, matrix: MatrixId(matrix), cols }
    }

    #[test]
    fn width_trigger_flushes() {
        let mut b = Batcher::new(BatchPolicy { max_batch_cols: 64, ..Default::default() });
        let now = Instant::now();
        assert!(b.push(pend(1, 0, 32), now).is_none());
        let batch = b.push(pend(2, 0, 32), now).unwrap();
        assert_eq!(batch.tokens, vec![1, 2]);
        assert_eq!(batch.total_cols, 64);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn groups_keyed_by_matrix() {
        let mut b = Batcher::new(BatchPolicy { max_batch_cols: 64, ..Default::default() });
        let now = Instant::now();
        assert!(b.push(pend(1, 0, 32), now).is_none());
        assert!(b.push(pend(2, 1, 32), now).is_none());
        assert_eq!(b.pending(), 2);
        let batch = b.push(pend(3, 0, 32), now).unwrap();
        assert_eq!(batch.matrix, MatrixId(0));
        assert_eq!(b.pending(), 1, "matrix 1's request still waits");
    }

    #[test]
    fn count_trigger_flushes() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch_cols: 10_000,
            max_batch_reqs: 3,
            ..Default::default()
        });
        let now = Instant::now();
        assert!(b.push(pend(1, 0, 8), now).is_none());
        assert!(b.push(pend(2, 0, 8), now).is_none());
        let batch = b.push(pend(3, 0, 8), now).unwrap();
        assert_eq!(batch.tokens.len(), 3);
    }

    #[test]
    fn deadline_trigger_flushes() {
        let policy = BatchPolicy { max_delay: Duration::from_millis(5), ..Default::default() };
        let mut b = Batcher::new(policy);
        let t0 = Instant::now();
        assert!(b.push(pend(1, 0, 8), t0).is_none());
        assert!(b.poll(t0 + Duration::from_millis(1)).is_empty());
        let flushed = b.poll(t0 + Duration::from_millis(6));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].tokens, vec![1]);
    }

    #[test]
    fn oversized_request_flushes_alone() {
        let mut b = Batcher::new(BatchPolicy { max_batch_cols: 64, ..Default::default() });
        let batch = b.push(pend(1, 0, 128), Instant::now()).unwrap();
        assert_eq!(batch.total_cols, 128);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = Batcher::new(BatchPolicy::default());
        let now = Instant::now();
        b.push(pend(1, 0, 8), now);
        b.push(pend(2, 1, 8), now);
        let drained = b.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn drain_hands_back_every_held_token_for_clean_failure() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch_cols: 10_000,
            max_batch_reqs: 1000,
            max_delay: Duration::from_secs(10),
        });
        let now = Instant::now();
        for t in 0..5 {
            assert!(b.push(pend(t, t % 2, 8), now).is_none());
        }
        let drained = b.drain();
        let mut tokens: Vec<u64> = drained.iter().flat_map(|batch| batch.tokens.clone()).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, vec![0, 1, 2, 3, 4], "no held request may be dropped");
        assert_eq!(b.pending(), 0);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let policy = BatchPolicy { max_delay: Duration::from_millis(5), ..Default::default() };
        let mut b = Batcher::new(policy);
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(pend(1, 0, 8), t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(5)));
    }
}
