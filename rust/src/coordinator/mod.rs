//! The L3 serving coordinator: matrix registry → router → dynamic batcher →
//! worker pool, with bounded-queue backpressure and serving metrics.
//!
//! Request lifecycle:
//!
//! ```text
//! client ──submit──► ingress (bounded) ──► router thread
//!                                           │  groups by matrix, flushes on
//!                                           │  width / count / deadline
//!                                           ▼
//!                                      exec queue ──► worker pool
//!                                                      │ fuse B columns,
//!                                                      │ one SpMM per batch
//!                                                      ▼
//!                                              reply channels (per request)
//! ```
//!
//! Engines: the native HRPB hot path (always available) and the AOT PJRT
//! artifact via [`crate::runtime::PjrtHandle`] (when artifacts are built and
//! the padded shape fits a bucket). Python never runs here.

pub mod batcher;
pub mod metrics;
pub mod registry;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use registry::{Entry, MatrixId, Registry};

use crate::formats::Dense;
use crate::planner::Planner;
use crate::runtime::PjrtHandle;
use crate::spmm::{Algo, SpmmEngine};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use self::metrics::PJRT_LANE;

/// Which engine executes batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnginePolicy {
    /// Always the native Rust HRPB engine.
    Native,
    /// Prefer the AOT PJRT artifact, fall back to native when no shape
    /// bucket fits or execution fails.
    PreferPjrt,
    /// Per-matrix adaptive routing: the [`crate::planner`] ranks every
    /// executable engine at registration time (synergy class + modeled
    /// runtimes + calibration + online feedback) and each matrix executes
    /// on its planned engine. Routing is fixed at registration: feedback
    /// demotion invalidates the plan cache and reroutes matrices registered
    /// *afterwards*; already-registered entries keep their engine.
    Auto,
}

impl EnginePolicy {
    pub fn parse(s: &str) -> Option<EnginePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EnginePolicy::Native),
            "pjrt" | "prefer-pjrt" => Some(EnginePolicy::PreferPjrt),
            "auto" => Some(EnginePolicy::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EnginePolicy::Native => "native",
            EnginePolicy::PreferPjrt => "pjrt",
            EnginePolicy::Auto => "auto",
        }
    }
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct Config {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    pub engine: EnginePolicy,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: std::thread::available_parallelism().map(|p| p.get().min(4)).unwrap_or(2),
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            engine: EnginePolicy::Native,
        }
    }
}

/// A served response.
#[derive(Debug)]
pub struct Response {
    pub c: Dense,
    /// Engine that produced it: "cutespmm-native" / "pjrt" under the fixed
    /// policies, or the planned engine's name (e.g. "sputnik", "cutespmm")
    /// under `EnginePolicy::Auto`.
    pub engine: &'static str,
    /// Submit → response latency.
    pub latency: Duration,
    /// Requests fused into the batch that served this response.
    pub batch_size: usize,
}

struct Request {
    token: u64,
    matrix: MatrixId,
    b: Dense,
    submitted: Instant,
    reply: Sender<Result<Response, String>>,
}

struct Job {
    matrix: MatrixId,
    reqs: Vec<Request>,
}

enum Ingress {
    Req(Request),
    Shutdown,
}

/// The running coordinator.
pub struct Coordinator {
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    planner: Option<Arc<Planner>>,
    ingress: SyncSender<Ingress>,
    next_token: AtomicU64,
    router: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start router + workers. `pjrt` supplies the AOT engine when the
    /// policy prefers it. `EnginePolicy::Auto` gets a default planner
    /// (A100 cost model); use [`Coordinator::start_with_planner`] to supply
    /// a calibrated one.
    pub fn start(config: Config, pjrt: Option<PjrtHandle>) -> Coordinator {
        let planner = match config.engine {
            EnginePolicy::Auto => Some(Arc::new(Planner::new(crate::gpumodel::Machine::a100()))),
            _ => None,
        };
        Coordinator::start_with_planner(config, pjrt, planner)
    }

    /// Start with an explicit planner (ignored unless the policy is `Auto`).
    pub fn start_with_planner(
        config: Config,
        pjrt: Option<PjrtHandle>,
        planner: Option<Arc<Planner>>,
    ) -> Coordinator {
        let planner = match config.engine {
            EnginePolicy::Auto => planner,
            _ => None,
        };
        let registry = Arc::new(Registry::new());
        let metrics = Arc::new(Metrics::default());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(config.queue_capacity);
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));

        // worker pool
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let job_rx = job_rx.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let pjrt = pjrt.clone();
            let planner = planner.clone();
            let engine = config.engine;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cutespmm-worker-{w}"))
                    .spawn(move || worker_loop(job_rx, registry, metrics, engine, pjrt, planner))
                    .expect("spawn worker"),
            );
        }

        // router thread
        let router = {
            let metrics = metrics.clone();
            let policy = config.batch;
            std::thread::Builder::new()
                .name("cutespmm-router".into())
                .spawn(move || router_loop(ingress_rx, job_tx, policy, metrics))
                .expect("spawn router")
        };

        Coordinator {
            registry,
            metrics,
            planner,
            ingress: ingress_tx,
            next_token: AtomicU64::new(0),
            router: Some(router),
            workers,
        }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The engine planner (present only under `EnginePolicy::Auto`).
    pub fn planner(&self) -> Option<&Arc<Planner>> {
        self.planner.as_ref()
    }

    /// Register a matrix (preprocess-once; see [`Registry`]). Under
    /// `EnginePolicy::Auto` this plans the matrix's engine.
    pub fn register(&self, name: &str, coo: &crate::formats::Coo) -> MatrixId {
        match &self.planner {
            Some(planner) => self.registry.register_planned(name, coo, planner),
            None => self.registry.register(name, coo),
        }
    }

    /// Submit a request; blocks only if the bounded ingress queue is full
    /// (backpressure). Returns the reply channel.
    pub fn submit(&self, matrix: MatrixId, b: Dense) -> Receiver<Result<Response, String>> {
        let (reply, rx) = channel();
        let req = Request {
            token: self.next_token.fetch_add(1, Ordering::Relaxed),
            matrix,
            b,
            submitted: Instant::now(),
            reply,
        };
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        if self.ingress.send(Ingress::Req(req)).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        }
        rx
    }

    /// Non-blocking submit: `Err` when the ingress queue is full.
    pub fn try_submit(
        &self,
        matrix: MatrixId,
        b: Dense,
    ) -> Result<Receiver<Result<Response, String>>, Dense> {
        let (reply, rx) = channel();
        let req = Request {
            token: self.next_token.fetch_add(1, Ordering::Relaxed),
            matrix,
            b,
            submitted: Instant::now(),
            reply,
        };
        match self.ingress.try_send(Ingress::Req(req)) {
            Ok(()) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(std::sync::mpsc::TrySendError::Full(Ingress::Req(r))) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(r.b)
            }
            Err(_) => panic!("coordinator stopped"),
        }
    }

    /// Convenience: submit and wait.
    pub fn call(&self, matrix: MatrixId, b: Dense) -> Result<Response, String> {
        self.submit(matrix, b)
            .recv()
            .map_err(|_| "coordinator dropped request".to_string())?
    }

    /// Graceful shutdown: drain in-flight work, join threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if self.router.is_some() {
            self.shutdown_inner();
        }
    }
}

fn router_loop(
    ingress: Receiver<Ingress>,
    job_tx: Sender<Job>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(policy);
    let mut held: HashMap<u64, Request> = HashMap::new();

    let flush = |batch: batcher::Batch, held: &mut HashMap<u64, Request>, job_tx: &Sender<Job>| {
        let reqs: Vec<Request> =
            batch.tokens.iter().filter_map(|t| held.remove(t)).collect();
        if reqs.is_empty() {
            return;
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let _ = job_tx.send(Job { matrix: batch.matrix, reqs });
    };

    loop {
        // wait bounded by the next batching deadline
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(req)) => {
                let now = Instant::now();
                let pending = batcher::Pending {
                    token: req.token,
                    matrix: req.matrix,
                    cols: req.b.cols,
                };
                held.insert(req.token, req);
                if let Some(batch) = batcher.push(pending, now) {
                    flush(batch, &mut held, &job_tx);
                }
                for batch in batcher.poll(now) {
                    flush(batch, &mut held, &job_tx);
                }
            }
            Ok(Ingress::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.poll(Instant::now()) {
                    flush(batch, &mut held, &job_tx);
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    for batch in batcher.drain() {
        flush(batch, &mut held, &job_tx);
    }
    // job_tx drops here; workers exit on channel close
}

fn worker_loop(
    jobs: Arc<Mutex<Receiver<Job>>>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    engine: EnginePolicy,
    pjrt: Option<PjrtHandle>,
    planner: Option<Arc<Planner>>,
) {
    loop {
        let job = {
            let guard = jobs.lock().unwrap();
            guard.recv()
        };
        let Ok(job) = job else { break };
        execute_job(job, &registry, &metrics, engine, pjrt.as_ref(), planner.as_deref());
    }
}

fn execute_job(
    job: Job,
    registry: &Registry,
    metrics: &Metrics,
    engine: EnginePolicy,
    pjrt: Option<&PjrtHandle>,
    planner: Option<&Planner>,
) {
    let batch_size = job.reqs.len();
    let Some(entry) = registry.get(job.matrix) else {
        for req in job.reqs {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            let _ = req.reply.send(Err(format!("unknown matrix {:?}", job.matrix)));
        }
        return;
    };

    // shape check before fusing
    let bad: Vec<bool> = job.reqs.iter().map(|r| r.b.rows != entry.cols).collect();
    let good_cols: usize =
        job.reqs.iter().zip(&bad).filter(|(_, &b)| !b).map(|(r, _)| r.b.cols).sum();

    // fuse B operands column-wise
    let mut fused = Dense::zeros(entry.cols, good_cols.max(1));
    let mut col = 0usize;
    for (req, &is_bad) in job.reqs.iter().zip(&bad) {
        if is_bad {
            continue;
        }
        for r in 0..entry.cols {
            fused.data[r * fused.cols + col..r * fused.cols + col + req.b.cols]
                .copy_from_slice(&req.b.row(r)[..req.b.cols]);
        }
        col += req.b.cols;
    }

    // execute (one launch per batch); `lane` tags the routing metrics and
    // `predicted_s` is the planner's corrected estimate for this batch
    // (0.0 when the route is unplanned).
    let t0 = Instant::now();
    let (c, engine_name, lane, predicted_s): (Dense, &'static str, Option<usize>, f64) =
        if good_cols == 0 {
            (Dense::zeros(entry.rows, 0), "none", None, 0.0)
        } else {
            // fixed policies only see unplanned entries, which always carry
            // the HRPB engine (see `Entry::engine`)
            let native =
                || entry.engine.as_ref().expect("fixed-policy entry carries the HRPB engine");
            match engine {
                EnginePolicy::PreferPjrt => {
                    let via_pjrt =
                        pjrt.and_then(|h| h.spmm(entry.hrpb.clone(), fused.clone()).ok());
                    match via_pjrt {
                        Some(c) => (c, "pjrt", Some(PJRT_LANE), 0.0),
                        None => {
                            (native().spmm(&fused), "cutespmm-native",
                             Some(Algo::Hrpb.index()), 0.0)
                        }
                    }
                }
                EnginePolicy::Native => {
                    (native().spmm(&fused), "cutespmm-native", Some(Algo::Hrpb.index()), 0.0)
                }
                EnginePolicy::Auto => {
                    let predicted = entry
                        .plan
                        .as_ref()
                        .map(|p| p.predicted_s_per_col * good_cols as f64)
                        .unwrap_or(0.0);
                    let lane = entry
                        .plan
                        .as_ref()
                        .map(|p| p.engine.index())
                        .unwrap_or(Algo::Hrpb.index());
                    (entry.exec.spmm(&fused), entry.exec.name(), Some(lane), predicted)
                }
            }
        };
    let exec_elapsed = t0.elapsed();
    metrics.exec_latency.record(exec_elapsed);
    if let Some(lane) = lane {
        let good_reqs = bad.iter().filter(|&&b| !b).count() as u64;
        metrics.record_route(lane, good_reqs, exec_elapsed, predicted_s);
        // close the loop: observed batch latency feeds engine demotion
        if let (Some(planner), Some(plan)) = (planner, entry.plan.as_ref()) {
            if predicted_s > 0.0 {
                planner.observe(plan.engine, predicted_s, exec_elapsed.as_secs_f64());
            }
        }
    }

    // split C back per request and reply
    let mut col = 0usize;
    for (req, is_bad) in job.reqs.into_iter().zip(bad) {
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        if is_bad {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            let _ = req.reply.send(Err(format!(
                "B rows {} != matrix cols {}",
                req.b.rows, entry.cols
            )));
            continue;
        }
        let mut out = Dense::zeros(entry.rows, req.b.cols);
        for r in 0..entry.rows {
            out.row_mut(r)
                .copy_from_slice(&c.row(r)[col..col + req.b.cols]);
        }
        col += req.b.cols;
        let latency = req.submitted.elapsed();
        metrics.request_latency.record(latency);
        metrics.responses.fetch_add(1, Ordering::Relaxed);
        metrics.add_flops(2.0 * entry.nnz as f64 * req.b.cols as f64);
        let _ = req.reply.send(Ok(Response {
            c: out,
            engine: engine_name,
            latency,
            batch_size,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::util::rng::Rng;

    fn small_coordinator(engine: EnginePolicy) -> (Coordinator, MatrixId, Coo) {
        let coord = Coordinator::start(
            Config { workers: 2, engine, ..Default::default() },
            None,
        );
        let coo = Coo::random(96, 128, 0.05, &mut Rng::new(400));
        let id = coord.register("test", &coo);
        (coord, id, coo)
    }

    #[test]
    fn serves_correct_results() {
        let (coord, id, coo) = small_coordinator(EnginePolicy::Native);
        let mut rng = Rng::new(401);
        let b = Dense::random(128, 16, &mut rng);
        let want = coo.to_dense().matmul(&b);
        let resp = coord.call(id, b).unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
        assert_eq!(resp.engine, "cutespmm-native");
        coord.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                batch: BatchPolicy {
                    max_batch_cols: 64,
                    max_batch_reqs: 64,
                    max_delay: Duration::from_millis(20),
                },
                ..Default::default()
            },
            None,
        );
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(402));
        let id = coord.register("m", &coo);
        let dense = coo.to_dense();

        // 4 × 16-wide requests fill the 64-col batch
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..4 {
            let b = Dense::random(64, 16, &mut Rng::new(500 + i));
            wants.push(dense.matmul(&b));
            rxs.push(coord.submit(id, b));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
            assert!(resp.batch_size >= 1);
        }
        let batches = coord.metrics().batches.load(Ordering::Relaxed);
        let fused = coord.metrics().batched_requests.load(Ordering::Relaxed);
        assert_eq!(fused, 4);
        assert!(batches <= 2, "4x16 wide requests should fuse (got {batches} batches)");
        coord.shutdown();
    }

    #[test]
    fn wrong_shape_is_rejected_not_crashed() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        let b = Dense::zeros(127, 8); // matrix has 128 cols
        let err = coord.call(id, b);
        assert!(err.is_err());
        // a good request still works afterwards
        let b = Dense::random(128, 8, &mut Rng::new(403));
        assert!(coord.call(id, b).is_ok());
        coord.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let (coord, _, _) = small_coordinator(EnginePolicy::Native);
        let err = coord.call(MatrixId(999), Dense::zeros(8, 8));
        assert!(err.is_err());
        coord.shutdown();
    }

    #[test]
    fn deadline_flush_serves_lone_requests() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                batch: BatchPolicy {
                    max_batch_cols: 4096,
                    max_batch_reqs: 1000,
                    max_delay: Duration::from_millis(1),
                },
                ..Default::default()
            },
            None,
        );
        let coo = Coo::random(32, 32, 0.2, &mut Rng::new(404));
        let id = coord.register("m", &coo);
        let b = Dense::random(32, 8, &mut Rng::new(405));
        let want = coo.to_dense().matmul(&b);
        let resp = coord.call(id, b).unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
        coord.shutdown();
    }

    #[test]
    fn metrics_accumulate() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        for i in 0..8 {
            let b = Dense::random(128, 8, &mut Rng::new(600 + i));
            coord.call(id, b).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.responses.load(Ordering::Relaxed), 8);
        assert_eq!(m.failures.load(Ordering::Relaxed), 0);
        assert!(m.request_latency.count() == 8);
        assert!(m.report().contains("responses=8"));
        coord.shutdown();
    }

    #[test]
    fn auto_policy_routes_by_synergy() {
        use crate::gen::{Family, MatrixSpec};
        use crate::synergy::Synergy;

        let coord = Coordinator::start(
            Config { workers: 2, engine: EnginePolicy::Auto, ..Default::default() },
            None,
        );
        assert!(coord.planner().is_some());

        // high synergy: dense-banded FEM regime (Emilia-like clustering)
        let high = MatrixSpec {
            name: "fem".into(),
            rows: 16_384,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.0 },
            seed: 7,
        }
        .generate();
        // low synergy: uniformly scattered (NotreDame-like)
        let low = Coo::random(4096, 4096, 8.0 / 4096.0, &mut Rng::new(8));

        let high_id = coord.register("high", &high);
        let low_id = coord.register("low", &low);

        let high_plan = coord.registry().get(high_id).unwrap().plan.clone().unwrap();
        let low_plan = coord.registry().get(low_id).unwrap().plan.clone().unwrap();
        assert_eq!(high_plan.synergy, Synergy::High, "alpha={}", high_plan.alpha);
        assert_eq!(high_plan.engine, Algo::Hrpb, "{}", high_plan.rationale);
        assert_eq!(low_plan.synergy, Synergy::Low, "alpha={}", low_plan.alpha);
        assert!(
            Algo::scalar_core().contains(&low_plan.engine),
            "low synergy chose {} ({})",
            low_plan.engine.name(),
            low_plan.rationale
        );

        // serve one request per matrix: results must match an independent
        // engine and the routing counters must attribute each batch to its
        // planned engine
        let mut rng = Rng::new(9);
        for (id, coo, plan_engine) in
            [(high_id, &high, high_plan.engine), (low_id, &low, low_plan.engine)]
        {
            let b = Dense::random(coo.cols, 8, &mut rng);
            let want = Algo::Csr.prepare(coo).spmm(&b);
            let resp = coord.call(id, b).unwrap();
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
            assert_eq!(resp.engine, plan_engine.name());
        }
        let m = coord.metrics();
        assert!(m.engine_requests(Algo::Hrpb) >= 1, "{}", m.report());
        assert!(m.engine_requests(low_plan.engine) >= 1, "{}", m.report());
        assert!(m.report().contains("routing="));
        coord.shutdown();
    }

    #[test]
    fn engine_policy_parses() {
        assert_eq!(EnginePolicy::parse("native"), Some(EnginePolicy::Native));
        assert_eq!(EnginePolicy::parse("pjrt"), Some(EnginePolicy::PreferPjrt));
        assert_eq!(EnginePolicy::parse("AUTO"), Some(EnginePolicy::Auto));
        assert_eq!(EnginePolicy::parse("gpu"), None);
        assert_eq!(EnginePolicy::Auto.name(), "auto");
    }

    #[test]
    fn many_threads_hammering() {
        let coord = Arc::new(Coordinator::start(
            Config { workers: 4, ..Default::default() },
            None,
        ));
        let coo = Coo::random(128, 160, 0.04, &mut Rng::new(406));
        let id = coord.register("m", &coo);
        let dense = Arc::new(coo.to_dense());
        std::thread::scope(|s| {
            for t in 0..8 {
                let coord = coord.clone();
                let dense = dense.clone();
                s.spawn(move || {
                    for i in 0..5 {
                        let b = Dense::random(160, 8, &mut Rng::new(t * 100 + i));
                        let want = dense.matmul(&b);
                        let resp = coord.call(id, b).unwrap();
                        assert!(resp.c.rel_fro_error(&want) < 1e-5);
                    }
                });
            }
        });
        assert_eq!(coord.metrics().responses.load(Ordering::Relaxed), 40);
    }
}
