//! The L3 serving coordinator: matrix registry → router → dynamic batcher →
//! worker pool, with bounded-queue backpressure and serving metrics.
//!
//! Request lifecycle:
//!
//! ```text
//! client ──submit──► ingress (bounded) ──► router thread
//!                                           │  groups by matrix, flushes on
//!                                           │  width / count / deadline
//!                                           ▼
//!                                      exec queue ──► worker pool
//!                                                      │ fuse B columns,
//!                                                      │ one SpMM per batch
//!                                                      ▼
//!                                              reply channels (per request)
//! ```
//!
//! Engines: the native HRPB hot path (always available) and the AOT PJRT
//! artifact via [`crate::runtime::PjrtHandle`] (when artifacts are built and
//! the padded shape fits a bucket). Python never runs here.
//!
//! With [`Config::qos`] set, the ingress is replaced by the [`crate::qos`]
//! admission layer: a bounded dual-priority queue whose admission rule sheds
//! load by planner-predicted cost and deadline feasibility, drained into the
//! batcher in priority order.
//!
//! Every reply channel carries a typed [`ServeError`] (PR 9): callers
//! dispatch on shed vs engine fault vs quarantine vs shutdown instead of
//! parsing strings. Engine panics are contained at the dispatch boundary
//! inside [`execute_job`] — a `catch_unwind` converts them into
//! `ServeError::EngineFault` for that batch only, RAII leases return the
//! arena buffers on the unwind path, and the per-matrix circuit breaker
//! ([`breaker`]) degrades the matrix to the scalar CSR fallback (and, if
//! that faults too, quarantines it) while everything else keeps serving.

pub mod batcher;
pub mod breaker;
mod error;
pub mod metrics;
pub mod registry;

pub use batcher::{BatchPolicy, Batcher};
pub use breaker::{Breaker, BreakerState};
pub use error::ServeError;
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{Entry, MatrixId, Registry};

use self::breaker::Route;
use crate::fault;
use crate::formats::Dense;
use crate::planner::Planner;
use crate::qos::{self, AdmissionQueue, Priority, QosConfig, RejectReason, Rejected, Ticket};
use crate::runtime::PjrtHandle;
use crate::spmm::exec::OutputArena;
use crate::spmm::{Algo, SpmmEngine};
use crate::synergy::Synergy;
use crate::trace::{self, SpanArgs, TraceConfig};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use self::metrics::PJRT_LANE;

/// Which engine executes batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnginePolicy {
    /// Always the native Rust HRPB engine.
    Native,
    /// Prefer the AOT PJRT artifact, fall back to native when no shape
    /// bucket fits or execution fails.
    PreferPjrt,
    /// Per-matrix adaptive routing: the [`crate::planner`] ranks every
    /// executable engine at registration time (synergy class + modeled
    /// runtimes + calibration + online feedback) and each matrix executes
    /// on its planned engine. Routing is fixed at registration: feedback
    /// demotion invalidates the plan cache and reroutes matrices registered
    /// *afterwards*; already-registered entries keep their engine.
    Auto,
}

impl EnginePolicy {
    pub fn parse(s: &str) -> Option<EnginePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EnginePolicy::Native),
            "pjrt" | "prefer-pjrt" => Some(EnginePolicy::PreferPjrt),
            "auto" => Some(EnginePolicy::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EnginePolicy::Native => "native",
            EnginePolicy::PreferPjrt => "pjrt",
            EnginePolicy::Auto => "auto",
        }
    }
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct Config {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    pub engine: EnginePolicy,
    /// QoS admission layer in front of the batcher: bounded dual-priority
    /// queuing, cost-aware shedding, deadline checks ([`crate::qos`]).
    /// `None` keeps the legacy bounded-channel ingress.
    pub qos: Option<QosConfig>,
    /// HRPB artifact directory: registrations warm-start from persisted
    /// artifacts and persist after cold builds
    /// ([`crate::hrpb::ArtifactStore`]); hit/miss/invalidated counters show
    /// up in the metrics report. `None` keeps registration in-memory only.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Runtime tracing ([`crate::trace`]): per-request span trees
    /// (admit → queue_wait → batch → exec → scatter) plus kernel profiling
    /// spans, with per-request sampling. Enabling installs the
    /// process-global trace session at startup; hold
    /// [`crate::trace::session_guard`] across start → drain.
    pub trace: TraceConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: std::thread::available_parallelism().map(|p| p.get().min(4)).unwrap_or(2),
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            engine: EnginePolicy::Native,
            qos: None,
            artifact_dir: None,
            trace: TraceConfig::default(),
        }
    }
}

/// A served response.
#[derive(Debug)]
pub struct Response {
    pub c: Dense,
    /// Engine that produced it: "cutespmm-native" / "pjrt" under the fixed
    /// policies, or the planned engine's name (e.g. "sputnik", "cutespmm")
    /// under `EnginePolicy::Auto`.
    pub engine: &'static str,
    /// Submit → response latency.
    pub latency: Duration,
    /// Requests fused into the batch that served this response.
    pub batch_size: usize,
}

struct Request {
    token: u64,
    matrix: MatrixId,
    b: Dense,
    submitted: Instant,
    priority: Priority,
    /// Planner-predicted execution cost (seconds); 0.0 on the legacy
    /// channel path. Drives the QoS downstream-backlog gauge.
    cost_s: f64,
    /// Whether this request records trace spans (the per-request sampling
    /// decision, made once at submit).
    traced: bool,
    /// When the request entered the batcher; set by the router only for
    /// traced requests, backs the `batch` span.
    batched_at: Option<Instant>,
    reply: Sender<Result<Response, ServeError>>,
}

struct Job {
    matrix: MatrixId,
    reqs: Vec<Request>,
}

enum Ingress {
    Req(Request),
    Shutdown,
}

/// How requests enter the router: the legacy bounded channel, or the QoS
/// admission queue ([`Config::qos`]).
enum IngressPath {
    Channel(SyncSender<Ingress>),
    Qos(Arc<AdmissionQueue<Request>>),
}

/// The running coordinator.
pub struct Coordinator {
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    planner: Option<Arc<Planner>>,
    /// Reusable output buffers (fused B + C) shared by the workers — the
    /// zero-allocation half of the execution runtime: in steady state every
    /// batch reuses released buffers and the miss counter stops moving.
    arena: Arc<OutputArena>,
    ingress: IngressPath,
    next_token: AtomicU64,
    /// Join handles live behind mutexes so [`Coordinator::drain`] works by
    /// shared reference — the network server holds the coordinator in an
    /// `Arc` and must still be able to run the QoS shutdown path.
    router: Mutex<Option<std::thread::JoinHandle<()>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Start router + workers. `pjrt` supplies the AOT engine when the
    /// policy prefers it. `EnginePolicy::Auto` gets a default planner
    /// (A100 cost model); use [`Coordinator::start_with_planner`] to supply
    /// a calibrated one.
    pub fn start(config: Config, pjrt: Option<PjrtHandle>) -> Coordinator {
        let planner = match config.engine {
            EnginePolicy::Auto => Some(Arc::new(Planner::new(crate::gpumodel::Machine::a100()))),
            _ => None,
        };
        Coordinator::start_with_planner(config, pjrt, planner)
    }

    /// Start with an explicit planner (ignored unless the policy is `Auto`).
    pub fn start_with_planner(
        config: Config,
        pjrt: Option<PjrtHandle>,
        planner: Option<Arc<Planner>>,
    ) -> Coordinator {
        let planner = match config.engine {
            EnginePolicy::Auto => planner,
            _ => None,
        };
        // tracing is process-global; only an *enabled* config installs (so
        // concurrent untraced coordinators never reset someone's session)
        if config.trace.enabled {
            trace::install(&config.trace);
        }
        // artifact warm start: an unopenable directory degrades to
        // in-memory registration rather than failing startup
        let registry = match &config.artifact_dir {
            Some(dir) => match crate::hrpb::ArtifactStore::open(dir) {
                Ok(store) => Arc::new(Registry::with_store(Arc::new(store))),
                Err(e) => {
                    eprintln!("warning: artifact store disabled: {e}");
                    Arc::new(Registry::new())
                }
            },
            None => Arc::new(Registry::new()),
        };
        let metrics = Arc::new(Metrics::default());
        // 2 buffers per worker (fused B + C) keeps steady state miss-free
        let arena = Arc::new(OutputArena::with_capacity(config.workers.max(1) * 2));
        // the job channel is bounded so the router backpressures instead of
        // hiding unbounded growth behind the batcher (with QoS enabled this
        // is what lets the admission queue fill and shed under saturation)
        let (job_tx, job_rx) = sync_channel::<Job>(config.workers.max(1) * 2);
        let job_rx = Arc::new(Mutex::new(job_rx));

        // worker pool
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let job_rx = job_rx.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let pjrt = pjrt.clone();
            let planner = planner.clone();
            let engine = config.engine;
            let arena = arena.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cutespmm-worker-{w}"))
                    .spawn(move || {
                        worker_loop(job_rx, registry, metrics, engine, pjrt, planner, arena)
                    })
                    .expect("spawn worker"),
            );
        }

        // router thread: QoS admission drain loop or the legacy channel loop
        let policy = config.batch;
        let (ingress, router) = match config.qos {
            Some(qos_config) => {
                let queue = Arc::new(AdmissionQueue::new(qos_config, config.workers.max(1)));
                let router = {
                    let metrics = metrics.clone();
                    let queue = queue.clone();
                    std::thread::Builder::new()
                        .name("cutespmm-qos-router".into())
                        .spawn(move || qos_router_loop(queue, job_tx, policy, metrics))
                        .expect("spawn qos router")
                };
                (IngressPath::Qos(queue), router)
            }
            None => {
                let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(config.queue_capacity);
                let router = {
                    let metrics = metrics.clone();
                    std::thread::Builder::new()
                        .name("cutespmm-router".into())
                        .spawn(move || router_loop(ingress_rx, job_tx, policy, metrics))
                        .expect("spawn router")
                };
                (IngressPath::Channel(ingress_tx), router)
            }
        };

        Coordinator {
            registry,
            metrics,
            planner,
            arena,
            ingress,
            next_token: AtomicU64::new(0),
            router: Mutex::new(Some(router)),
            workers: Mutex::new(workers),
        }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The workers' shared output-buffer arena (hit/miss counters back the
    /// zero-allocation steady-state assertion).
    pub fn arena(&self) -> &OutputArena {
        &self.arena
    }

    /// The engine planner (present only under `EnginePolicy::Auto`).
    pub fn planner(&self) -> Option<&Arc<Planner>> {
        self.planner.as_ref()
    }

    /// Register a matrix (preprocess-once; see [`Registry`]). Under
    /// `EnginePolicy::Auto` this plans the matrix's engine. With an artifact
    /// store attached, registration warm-starts from disk and the store's
    /// hit/miss/invalidated counters are mirrored into the metrics report.
    pub fn register(&self, name: &str, coo: &crate::formats::Coo) -> MatrixId {
        let id = match &self.planner {
            Some(planner) => self.registry.register_planned(name, coo, planner),
            None => self.registry.register(name, coo),
        };
        if let Some(store) = self.registry.store() {
            self.metrics.sync_artifacts(store.stats());
        }
        // mirror reorder gains (planner-gated row permutations) from every
        // registered entry into the report's `reorder=[...]` section
        let mut snap = metrics::ReorderSnapshot::default();
        for e in self.registry.entries() {
            if let Some(g) = e.reorder {
                snap.add(g);
            }
        }
        self.metrics.sync_reorder(snap);
        id
    }

    /// Submit a request on the normal lane with no deadline. Under the
    /// legacy channel ingress this blocks only if the bounded queue is full
    /// (backpressure); under QoS a shed request surfaces as a typed error
    /// on the reply channel.
    pub fn submit(&self, matrix: MatrixId, b: Dense) -> Receiver<Result<Response, ServeError>> {
        self.submit_with(matrix, b, Priority::Normal, None)
    }

    /// Submit with a QoS priority and optional deadline. Without
    /// `Config::qos` the priority and deadline are ignored (legacy channel
    /// semantics); with it, admission rejections arrive as typed
    /// [`ServeError`]s on the reply channel (see
    /// [`Coordinator::submit_qos`] for the `Result`-shaped variant).
    pub fn submit_with(
        &self,
        matrix: MatrixId,
        b: Dense,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Receiver<Result<Response, ServeError>> {
        match &self.ingress {
            IngressPath::Channel(_) => self.submit_channel(matrix, b),
            IngressPath::Qos(_) => match self.submit_qos(matrix, b, priority, deadline) {
                Ok(rx) => rx,
                Err((err, _b)) => {
                    let (reply, rx) = channel();
                    let _ = reply.send(Err(err));
                    rx
                }
            },
        }
    }

    /// Typed QoS submit (requires `Config::qos`): the admission layer may
    /// shed the request immediately — `Err` carries the typed verdict
    /// ([`ServeError::Shed`] with reason + estimated wait,
    /// [`ServeError::Quarantined`] for a breaker-quarantined matrix, or
    /// [`ServeError::Misconfigured`] when QoS is not enabled) and returns
    /// the B operand. `deadline` overrides the configured default deadline.
    pub fn submit_qos(
        &self,
        matrix: MatrixId,
        b: Dense,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Response, ServeError>>, (ServeError, Dense)> {
        let IngressPath::Qos(queue) = &self.ingress else {
            return Err((
                ServeError::Misconfigured(
                    "submit_qos requires Config::qos (the admission layer is not enabled)",
                ),
                b,
            ));
        };
        // per-matrix cost lookup: planner-predicted seconds for this request
        let (cost_s, expensive) = match self.registry.get(matrix) {
            Some(entry) => {
                // quarantined matrices are rejected at admission — no point
                // queueing work the worker will refuse
                if entry.breaker.state() == BreakerState::Quarantined {
                    self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    self.metrics.quarantined_rejects.fetch_add(1, Ordering::Relaxed);
                    return Err((ServeError::Quarantined { matrix: entry.name.clone() }, b));
                }
                (entry.cost_s_per_col * b.cols as f64, entry.synergy == Synergy::Low)
            }
            // unknown matrices carry zero cost; the worker fails them with
            // its own typed error
            None => (0.0, false),
        };
        let mut ticket = Ticket::new(priority, cost_s);
        ticket.deadline = deadline;
        ticket.expensive = expensive;
        let (reply, rx) = channel();
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let traced = trace::sample(token);
        let submitted = Instant::now();
        let req = Request {
            token,
            matrix,
            b,
            submitted,
            priority,
            cost_s,
            traced,
            batched_at: None,
            reply,
        };
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        // gauge up *before* the request becomes visible to the router, so a
        // fast router+worker can never fetch_sub past zero and wrap it
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        match queue.submit(ticket, req, self.metrics.qos_downstream_cost_s()) {
            Ok(()) => {
                self.metrics.record_admitted(priority);
                self.metrics.set_qos_depth(priority, queue.depth(priority));
                if traced {
                    trace::record(
                        trace::Kind::Request,
                        "admit",
                        submitted,
                        token,
                        SpanArgs::new().with("admitted", 1).with("lane", priority.index() as u64),
                    );
                }
                Ok(rx)
            }
            Err((rejected, req)) => {
                self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_shed(priority, rejected.reason);
                if traced {
                    trace::record(
                        trace::Kind::Request,
                        "admit",
                        submitted,
                        token,
                        SpanArgs::new().with("admitted", 0).with("lane", priority.index() as u64),
                    );
                }
                Err((ServeError::Shed(rejected), req.b))
            }
        }
    }

    fn submit_channel(
        &self,
        matrix: MatrixId,
        b: Dense,
    ) -> Receiver<Result<Response, ServeError>> {
        let IngressPath::Channel(tx) = &self.ingress else {
            unreachable!("submit_channel is only called on the channel path");
        };
        let (reply, rx) = channel();
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let traced = trace::sample(token);
        let submitted = Instant::now();
        let req = Request {
            token,
            matrix,
            b,
            submitted,
            priority: Priority::Normal,
            cost_s: 0.0,
            traced,
            batched_at: None,
            reply,
        };
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let admitted = match tx.send(Ingress::Req(req)) {
            Ok(()) => true,
            // shutdown raced the submission: the router is gone, so answer
            // the reply channel with the typed error instead of letting the
            // caller's recv() see a silently dropped sender
            Err(std::sync::mpsc::SendError(Ingress::Req(r))) => {
                self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = r.reply.send(Err(ServeError::Shutdown));
                false
            }
            Err(_) => unreachable!("send returns the Ingress::Req it was given"),
        };
        if traced {
            trace::record(
                trace::Kind::Request,
                "admit",
                submitted,
                token,
                SpanArgs::new().with("admitted", admitted as u64),
            );
        }
        rx
    }

    /// Non-blocking submit: `Err` carries the typed verdict
    /// ([`ServeError::Busy`] when the legacy ingress channel is full,
    /// [`ServeError::Shed`] when QoS admission sheds,
    /// [`ServeError::Shutdown`] when the coordinator stopped) and returns
    /// the operand.
    pub fn try_submit(
        &self,
        matrix: MatrixId,
        b: Dense,
    ) -> Result<Receiver<Result<Response, ServeError>>, (ServeError, Dense)> {
        let tx = match &self.ingress {
            IngressPath::Channel(tx) => tx,
            IngressPath::Qos(_) => {
                return self.submit_qos(matrix, b, Priority::Normal, None);
            }
        };
        let (reply, rx) = channel();
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let traced = trace::sample(token);
        let submitted = Instant::now();
        let req = Request {
            token,
            matrix,
            b,
            submitted,
            priority: Priority::Normal,
            cost_s: 0.0,
            traced,
            batched_at: None,
            reply,
        };
        // `requests` counts everything offered (matching the QoS path and
        // the blocking submit), whether or not it is accepted
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let outcome = match tx.try_send(Ingress::Req(req)) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(std::sync::mpsc::TrySendError::Full(Ingress::Req(r))) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err((ServeError::Busy, r.b))
            }
            // shutdown raced the submission — a typed error, not a panic
            Err(std::sync::mpsc::TrySendError::Disconnected(Ingress::Req(r))) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err((ServeError::Shutdown, r.b))
            }
            Err(_) => unreachable!("try_send returns the Ingress::Req it was given"),
        };
        if traced {
            trace::record(
                trace::Kind::Request,
                "admit",
                submitted,
                token,
                SpanArgs::new().with("admitted", outcome.is_ok() as u64),
            );
        }
        outcome
    }

    /// Convenience: submit and wait. A dropped reply channel (shutdown
    /// racing the request) is a typed [`ServeError::Shutdown`], not a
    /// panic.
    pub fn call(&self, matrix: MatrixId, b: Dense) -> Result<Response, ServeError> {
        self.submit(matrix, b).recv().map_err(|_| ServeError::Shutdown)?
    }

    /// Graceful shutdown. Legacy ingress: drain in-flight work, join
    /// threads. QoS ingress: close admission, fail everything still queued
    /// (and still grouped in the batcher) with typed `shutdown` rejections,
    /// finish jobs already dispatched to workers, join threads.
    pub fn shutdown(self) {
        self.drain();
    }

    /// [`Coordinator::shutdown`] by shared reference — the same QoS
    /// shutdown path, callable through an `Arc` (the network server and
    /// the shard router's graceful drain both hold shared coordinators).
    /// Idempotent: a second drain (or the eventual `Drop`) is a no-op.
    pub fn drain(&self) {
        match &self.ingress {
            IngressPath::Channel(tx) => {
                // second drain: the router already exited, the send fails
                // harmlessly on the disconnected channel
                let _ = tx.send(Ingress::Shutdown);
            }
            IngressPath::Qos(queue) => {
                // AdmissionQueue::close is idempotent: a second close
                // returns an empty drain
                for (_ticket, req) in queue.close() {
                    reject_shutdown(&self.metrics, req);
                }
            }
        }
        if let Some(r) = self.router.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = r.join();
        }
        let handles: Vec<_> =
            self.workers.lock().unwrap_or_else(|p| p.into_inner()).drain(..).collect();
        for w in handles {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Move a flushed batch's held requests into a [`Job`] and dispatch it
/// (shared by both router loops; blocks when the bounded job channel is
/// full — that backpressure is what lets the admission queue fill).
fn flush_batch(
    batch: batcher::Batch,
    held: &mut HashMap<u64, Request>,
    job_tx: &SyncSender<Job>,
    metrics: &Metrics,
) {
    let reqs: Vec<Request> = batch.tokens.iter().filter_map(|t| held.remove(t)).collect();
    if reqs.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
    for req in &reqs {
        // batcher dwell time per traced request: entered (batched_at) →
        // flushed into a job (now)
        if let Some(t) = req.batched_at {
            trace::record(
                trace::Kind::Request,
                "batch",
                t,
                req.token,
                SpanArgs::new()
                    .with("reqs", reqs.len() as u64)
                    .with("cols", batch.total_cols as u64),
            );
        }
    }
    let _ = job_tx.send(Job { matrix: batch.matrix, reqs });
}

/// Feed one request into the batcher and flush whatever its arrival
/// triggers (width/count trigger plus any deadline-expired groups) — the
/// shared per-item step of both router loops.
fn feed_batcher(
    mut req: Request,
    batcher: &mut Batcher,
    held: &mut HashMap<u64, Request>,
    job_tx: &SyncSender<Job>,
    metrics: &Metrics,
) {
    let now = Instant::now();
    if req.traced {
        req.batched_at = Some(now);
    }
    let pending = batcher::Pending { token: req.token, matrix: req.matrix, cols: req.b.cols };
    held.insert(req.token, req);
    if let Some(batch) = batcher.push(pending, now) {
        flush_batch(batch, held, job_tx, metrics);
    }
    for batch in batcher.poll(now) {
        flush_batch(batch, held, job_tx, metrics);
    }
}

/// Fail one request with a typed shutdown rejection (shared by the QoS
/// router's batcher drain and the coordinator's admission-queue drain).
fn reject_shutdown(metrics: &Metrics, req: Request) {
    metrics.rejected.fetch_add(1, Ordering::Relaxed);
    metrics.record_shed(req.priority, RejectReason::Shutdown);
    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
    let rejected = Rejected {
        reason: RejectReason::Shutdown,
        est_wait: Duration::ZERO,
        priority: req.priority,
    };
    let _ = req.reply.send(Err(ServeError::Shed(rejected)));
}

fn router_loop(
    ingress: Receiver<Ingress>,
    job_tx: SyncSender<Job>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(policy);
    let mut held: HashMap<u64, Request> = HashMap::new();

    loop {
        // wait bounded by the next batching deadline
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(req)) => {
                if req.traced {
                    // channel dwell: submit → router pickup
                    trace::record(
                        trace::Kind::Request,
                        "queue_wait",
                        req.submitted,
                        req.token,
                        SpanArgs::new(),
                    );
                }
                feed_batcher(req, &mut batcher, &mut held, &job_tx, &metrics);
            }
            Ok(Ingress::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.poll(Instant::now()) {
                    flush_batch(batch, &mut held, &job_tx, &metrics);
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    for batch in batcher.drain() {
        flush_batch(batch, &mut held, &job_tx, &metrics);
    }
    // job_tx drops here; workers exit on channel close
}

/// The QoS drain loop: feeds the batcher from the admission queue in
/// priority order, records per-lane queue waits and the downstream-backlog
/// gauge, and — on graceful shutdown — fails everything still grouped in
/// the batcher with typed rejections ([`Batcher::drain`] hands the pending
/// groups back) instead of dropping it on the floor.
fn qos_router_loop(
    queue: Arc<AdmissionQueue<Request>>,
    job_tx: SyncSender<Job>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(policy);
    let mut held: HashMap<u64, Request> = HashMap::new();

    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match queue.pop_timeout(timeout) {
            qos::Pop::Item(ticket, req) => {
                metrics.record_queue_wait(ticket.priority, ticket.enqueued.elapsed());
                metrics.set_qos_depth(ticket.priority, queue.depth(ticket.priority));
                if req.traced {
                    // admission-queue dwell: the same enqueued → drained
                    // interval the per-lane wait histogram records
                    trace::record(
                        trace::Kind::Request,
                        "queue_wait",
                        ticket.enqueued,
                        req.token,
                        SpanArgs::new().with("lane", ticket.priority.index() as u64),
                    );
                }
                // from here until the worker replies this request's cost is
                // downstream backlog the admission estimator must still see
                metrics.add_qos_downstream(req.cost_s);
                feed_batcher(req, &mut batcher, &mut held, &job_tx, &metrics);
            }
            qos::Pop::TimedOut => {
                for batch in batcher.poll(Instant::now()) {
                    flush_batch(batch, &mut held, &job_tx, &metrics);
                }
            }
            qos::Pop::Closed => break,
        }
    }
    // graceful shutdown: pending groups are failed cleanly with typed
    // rejections; jobs already sent to workers still execute
    for batch in batcher.drain() {
        for token in batch.tokens {
            let Some(req) = held.remove(&token) else { continue };
            metrics.sub_qos_downstream(req.cost_s);
            reject_shutdown(&metrics, req);
        }
    }
    // job_tx drops here; workers exit on channel close
}

fn worker_loop(
    jobs: Arc<Mutex<Receiver<Job>>>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    engine: EnginePolicy,
    pjrt: Option<PjrtHandle>,
    planner: Option<Arc<Planner>>,
    arena: Arc<OutputArena>,
) {
    loop {
        let job = {
            let guard = jobs.lock().unwrap();
            guard.recv()
        };
        let Ok(job) = job else { break };
        execute_job(job, &registry, &metrics, engine, pjrt.as_ref(), planner.as_deref(), &arena);
    }
}

/// RAII lease on an arena buffer: the buffer returns to the arena on every
/// exit path out of [`execute_job`] — including the path where a contained
/// engine panic abandons the batch mid-flight — so a faulting engine can
/// never leak the fused-B/C buffers out of the steady-state pool.
struct ArenaLease<'a> {
    arena: &'a OutputArena,
    buf: Option<Dense>,
}

impl<'a> ArenaLease<'a> {
    fn acquire(arena: &'a OutputArena, rows: usize, cols: usize) -> ArenaLease<'a> {
        ArenaLease { arena, buf: Some(arena.acquire(rows, cols)) }
    }

    /// Wrap an externally produced buffer (e.g. the PJRT boundary's owned
    /// output) so it joins the pool on release like an arena-born one.
    fn adopt(arena: &'a OutputArena, buf: Dense) -> ArenaLease<'a> {
        ArenaLease { arena, buf: Some(buf) }
    }

    fn get(&self) -> &Dense {
        self.buf.as_ref().expect("lease holds a buffer")
    }

    fn get_mut(&mut self) -> &mut Dense {
        self.buf.as_mut().expect("lease holds a buffer")
    }
}

impl Drop for ArenaLease<'_> {
    fn drop(&mut self) {
        if let Some(b) = self.buf.take() {
            self.arena.release(b);
        }
    }
}

/// Extract a readable message from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// One engine dispatch behind the panic-containment boundary: the fault
/// injection points fire first (so chaos runs exercise the *real*
/// containment path), then the engine writes into the leased output. A
/// panic anywhere inside becomes an `Err` with the payload's message —
/// the worker thread never unwinds.
fn contained_spmm(
    key: &str,
    engine: &dyn SpmmEngine,
    fused: &Dense,
    out: &mut Dense,
) -> Result<(), String> {
    let r = catch_unwind(AssertUnwindSafe(|| {
        fault::slow_exec(key);
        fault::kernel_panic(key);
        engine.spmm_into(fused, out);
    }));
    r.map_err(panic_message)
}

/// Mirror per-matrix breaker states, the aggregate breaker counters, and
/// the fault-injection fired total into the metrics registry (the
/// `faults=[...]` / `breakers=[...]` report sections).
fn mirror_breakers(registry: &Registry, metrics: &Metrics) {
    let mut snap = Vec::new();
    let mut totals = breaker::BreakerCounters::default();
    for e in registry.entries() {
        let c = e.breaker.counters();
        totals.opens += c.opens;
        totals.closes += c.closes;
        totals.probes += c.probes;
        let state = e.breaker.state();
        if state != BreakerState::Closed {
            snap.push(metrics::BreakerEntry { matrix: e.name.clone(), state: state.name() });
        }
    }
    metrics.sync_breakers(snap, totals);
    metrics.sync_injected(fault::fired_total());
}

fn execute_job(
    job: Job,
    registry: &Registry,
    metrics: &Metrics,
    engine: EnginePolicy,
    pjrt: Option<&PjrtHandle>,
    planner: Option<&Planner>,
    arena: &OutputArena,
) {
    let batch_size = job.reqs.len();
    let Some(entry) = registry.get(job.matrix) else {
        for req in job.reqs {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            metrics.sub_qos_downstream(req.cost_s);
            let _ = req.reply.send(Err(ServeError::UnknownMatrix(job.matrix)));
        }
        return;
    };

    // quarantined matrices are rejected as a batch before any work (a
    // plain state read — routing side effects stay per-executed-batch)
    if entry.breaker.state() == BreakerState::Quarantined {
        for req in job.reqs {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            metrics.quarantined_rejects.fetch_add(1, Ordering::Relaxed);
            metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            metrics.sub_qos_downstream(req.cost_s);
            let _ =
                req.reply.send(Err(ServeError::Quarantined { matrix: entry.name.clone() }));
        }
        mirror_breakers(registry, metrics);
        return;
    }

    // shape check before fusing
    let bad: Vec<bool> = job.reqs.iter().map(|r| r.b.rows != entry.cols).collect();
    let good_cols: usize =
        job.reqs.iter().zip(&bad).filter(|(_, &b)| !b).map(|(r, _)| r.b.cols).sum();

    // breaker routing: consulted once per batch that actually executes
    // (all-bad-shape batches must not consume a half-open probe slot)
    let route = if good_cols > 0 { entry.breaker.route() } else { Route::Primary };

    // fuse B operands column-wise into an arena buffer (steady state: a
    // reused allocation, zeroed in place)
    let mut fused = ArenaLease::acquire(arena, entry.cols, good_cols.max(1));
    {
        let f = fused.get_mut();
        let mut col = 0usize;
        for (req, &is_bad) in job.reqs.iter().zip(&bad) {
            if is_bad {
                continue;
            }
            for r in 0..entry.cols {
                f.data[r * f.cols + col..r * f.cols + col + req.b.cols]
                    .copy_from_slice(&req.b.row(r)[..req.b.cols]);
            }
            col += req.b.cols;
        }
    }

    // the planner's corrected estimate for this batch — only a planned
    // engine on its planned route carries one (the CSR fallback is priced
    // by observation, not by the faulted plan)
    let predicted_s = match (engine, route) {
        (EnginePolicy::Auto, Route::Primary | Route::Probe) => entry
            .plan
            .as_ref()
            .map(|p| p.predicted_s_per_col * good_cols as f64)
            .unwrap_or(0.0),
        _ => 0.0,
    };

    // execute (one launch per batch) with `spmm_into` writing into an arena
    // lease — the native paths allocate nothing in steady state; `lane`
    // tags the routing metrics. Engine panics are contained inside
    // `contained_spmm`: an `Err` fails only this batch, typed.
    let t0 = Instant::now();
    type ExecOk<'a> = (ArenaLease<'a>, &'static str, Option<usize>);
    let exec_outcome: Result<ExecOk<'_>, (&'static str, String)> = if good_cols == 0 {
        Ok((ArenaLease::adopt(arena, Dense::zeros(entry.rows, 0)), "none", None))
    } else if route == Route::Fallback {
        // breaker open: serve on the scalar CSR fallback engine
        let key = format!("{}@{}", entry.fallback.name(), entry.name);
        let mut c = ArenaLease::acquire(arena, entry.rows, good_cols);
        let r = contained_spmm(&key, entry.fallback.as_ref(), fused.get(), c.get_mut());
        match r {
            Ok(()) => Ok((c, entry.fallback.name(), Some(Algo::Csr.index()))),
            Err(detail) => Err((entry.fallback.name(), detail)),
        }
    } else {
        // Route::Primary / Route::Probe — the policy's planned engine.
        // Fixed policies only see unplanned entries, which always carry
        // the HRPB engine (see `Entry::engine`).
        match engine {
            EnginePolicy::PreferPjrt => {
                // the fused operand is cloned for the PJRT boundary only
                // when a handle actually exists; the handle-less fallback
                // goes straight to native with no copy
                let via_pjrt = match pjrt {
                    Some(h) => h.spmm(entry.hrpb.clone(), fused.get().clone()).ok(),
                    None => None,
                };
                match via_pjrt {
                    Some(c) => Ok((ArenaLease::adopt(arena, c), "pjrt", Some(PJRT_LANE))),
                    None => {
                        let native = entry
                            .engine
                            .as_ref()
                            .expect("fixed-policy entry carries the HRPB engine");
                        let key = format!("{}@{}", native.name(), entry.name);
                        let mut c = ArenaLease::acquire(arena, entry.rows, good_cols);
                        let r =
                            contained_spmm(&key, native.as_ref(), fused.get(), c.get_mut());
                        match r {
                            Ok(()) => Ok((c, "cutespmm-native", Some(Algo::Hrpb.index()))),
                            Err(detail) => Err(("cutespmm-native", detail)),
                        }
                    }
                }
            }
            EnginePolicy::Native => {
                let native = entry
                    .engine
                    .as_ref()
                    .expect("fixed-policy entry carries the HRPB engine");
                let key = format!("{}@{}", native.name(), entry.name);
                let mut c = ArenaLease::acquire(arena, entry.rows, good_cols);
                let r = contained_spmm(&key, native.as_ref(), fused.get(), c.get_mut());
                match r {
                    Ok(()) => Ok((c, "cutespmm-native", Some(Algo::Hrpb.index()))),
                    Err(detail) => Err(("cutespmm-native", detail)),
                }
            }
            EnginePolicy::Auto => {
                let lane =
                    entry.plan.as_ref().map(|p| p.engine.index()).unwrap_or(Algo::Hrpb.index());
                let key = format!("{}@{}", entry.exec.name(), entry.name);
                let mut c = ArenaLease::acquire(arena, entry.rows, good_cols);
                let r = contained_spmm(&key, entry.exec.as_ref(), fused.get(), c.get_mut());
                match r {
                    Ok(()) => Ok((c, entry.exec.name(), Some(lane))),
                    Err(detail) => Err((entry.exec.name(), detail)),
                }
            }
        }
    };
    let exec_elapsed = t0.elapsed();
    metrics.exec_latency.record(exec_elapsed);

    match exec_outcome {
        Ok((c, engine_name, lane)) => {
            if good_cols > 0 {
                entry.breaker.record_success(route);
            }
            // the exec span shares t0 with `exec_latency` / `record_route`,
            // so the trace experiment can reconcile summed exec spans
            // against the engine-lane observed_us counters by construction
            if job.reqs.iter().any(|r| r.traced) {
                let token = job.reqs.first().map(|r| r.token).unwrap_or(trace::NO_TOKEN);
                trace::record(
                    trace::Kind::Request,
                    "exec",
                    t0,
                    token,
                    SpanArgs::engine(engine_name)
                        .with("reqs", batch_size as u64)
                        .with("cols", good_cols as u64),
                );
            }
            if let Some(lane) = lane {
                let good_reqs = bad.iter().filter(|&&b| !b).count() as u64;
                metrics.record_route(lane, good_reqs, exec_elapsed, predicted_s);
                if route == Route::Fallback {
                    metrics.fallback_requests.fetch_add(good_reqs, Ordering::Relaxed);
                }
                // close the loop: observed batch latency feeds engine demotion
                if let (Some(planner), Some(plan)) = (planner, entry.plan.as_ref()) {
                    if predicted_s > 0.0 {
                        planner.observe(plan.engine, predicted_s, exec_elapsed.as_secs_f64());
                    }
                }
            }

            // split C back per request and reply
            let mut col = 0usize;
            for (req, is_bad) in job.reqs.into_iter().zip(bad) {
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                metrics.sub_qos_downstream(req.cost_s);
                if is_bad {
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(Err(ServeError::ShapeMismatch {
                        got: req.b.rows,
                        want: entry.cols,
                    }));
                    continue;
                }
                let t_scatter =
                    if req.traced { Some((Instant::now(), req.b.cols)) } else { None };
                let mut out = Dense::zeros(entry.rows, req.b.cols);
                let cv = c.get();
                for r in 0..entry.rows {
                    out.row_mut(r).copy_from_slice(&cv.row(r)[col..col + req.b.cols]);
                }
                col += req.b.cols;
                let latency = req.submitted.elapsed();
                metrics.request_latency.record(latency);
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                metrics.add_flops(2.0 * entry.nnz as f64 * req.b.cols as f64);
                let token = req.token;
                let _ = req.reply.send(Ok(Response {
                    c: out,
                    engine: engine_name,
                    latency,
                    batch_size,
                }));
                if let Some((t, cols)) = t_scatter {
                    // split-C copy + reply epilogue per request
                    trace::record(
                        trace::Kind::Request,
                        "scatter",
                        t,
                        token,
                        SpanArgs::new().with("cols", cols as u64),
                    );
                }
            }
            // per-request outputs are copied out above; the lease drop
            // returns the C buffer to the arena for the next batch
            drop(c);
            if route != Route::Primary {
                mirror_breakers(registry, metrics);
            }
        }
        Err((engine_name, detail)) => {
            // contained engine fault: only this batch's requests fail, the
            // worker thread survives, and the breaker/planner learn from it
            if matches!(route, Route::Primary | Route::Probe) {
                // re-price through the feedback machinery: a faulting
                // engine is effectively unusable, so feed the demotion
                // tracker a massive overshoot against its prediction
                if let (Some(planner), Some(plan)) = (planner, entry.plan.as_ref()) {
                    if predicted_s > 0.0 {
                        planner.observe(plan.engine, predicted_s, predicted_s * 100.0);
                    }
                }
            }
            entry.breaker.record_fault(route);
            for (req, is_bad) in job.reqs.into_iter().zip(bad) {
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                metrics.sub_qos_downstream(req.cost_s);
                metrics.failures.fetch_add(1, Ordering::Relaxed);
                if is_bad {
                    let _ = req.reply.send(Err(ServeError::ShapeMismatch {
                        got: req.b.rows,
                        want: entry.cols,
                    }));
                    continue;
                }
                metrics.engine_faults.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(ServeError::EngineFault {
                    matrix: entry.name.clone(),
                    engine: engine_name,
                    detail: detail.clone(),
                }));
            }
            mirror_breakers(registry, metrics);
        }
    }
    drop(fused);
    metrics.sync_arena(arena.hits(), arena.misses());
    if trace::enabled() {
        let totals = trace::ring_totals();
        metrics.sync_trace(totals.recorded, totals.dropped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::util::rng::Rng;

    fn small_coordinator(engine: EnginePolicy) -> (Coordinator, MatrixId, Coo) {
        let coord = Coordinator::start(
            Config { workers: 2, engine, ..Default::default() },
            None,
        );
        let coo = Coo::random(96, 128, 0.05, &mut Rng::new(400));
        let id = coord.register("test", &coo);
        (coord, id, coo)
    }

    #[test]
    fn serves_correct_results() {
        let (coord, id, coo) = small_coordinator(EnginePolicy::Native);
        let mut rng = Rng::new(401);
        let b = Dense::random(128, 16, &mut rng);
        let want = coo.to_dense().matmul(&b);
        let resp = coord.call(id, b).unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
        assert_eq!(resp.engine, "cutespmm-native");
        coord.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                batch: BatchPolicy {
                    max_batch_cols: 64,
                    max_batch_reqs: 64,
                    max_delay: Duration::from_millis(20),
                },
                ..Default::default()
            },
            None,
        );
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(402));
        let id = coord.register("m", &coo);
        let dense = coo.to_dense();

        // 4 × 16-wide requests fill the 64-col batch
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..4 {
            let b = Dense::random(64, 16, &mut Rng::new(500 + i));
            wants.push(dense.matmul(&b));
            rxs.push(coord.submit(id, b));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
            assert!(resp.batch_size >= 1);
        }
        let batches = coord.metrics().batches.load(Ordering::Relaxed);
        let fused = coord.metrics().batched_requests.load(Ordering::Relaxed);
        assert_eq!(fused, 4);
        assert!(batches <= 2, "4x16 wide requests should fuse (got {batches} batches)");
        coord.shutdown();
    }

    /// Acceptance: `spmm_into` + arena makes steady-state serving
    /// allocation-free on the output path — after the first batch warms the
    /// two buffers (fused B + C), every later batch is an arena hit.
    #[test]
    fn steady_state_serving_does_zero_output_allocations() {
        let coord = Coordinator::start(Config { workers: 1, ..Default::default() }, None);
        let coo = Coo::random(128, 160, 0.05, &mut Rng::new(420));
        let id = coord.register("m", &coo);
        let dense = coo.to_dense();
        for i in 0..12u64 {
            let b = Dense::random(160, 8, &mut Rng::new(800 + i));
            let want = dense.matmul(&b);
            let resp = coord.call(id, b).unwrap();
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
        }
        let arena = coord.arena();
        assert!(
            arena.misses() <= 2,
            "only batch-1 warmup may allocate (misses {})",
            arena.misses()
        );
        assert!(arena.hits() >= 22, "later batches must reuse (hits {})", arena.hits());
        assert!(coord.metrics().report().contains("arena=[hits="), "{}", coord.metrics().report());
        coord.shutdown();
    }

    #[test]
    fn wrong_shape_is_rejected_not_crashed() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        let b = Dense::zeros(127, 8); // matrix has 128 cols
        let err = coord.call(id, b).unwrap_err();
        assert!(matches!(err, ServeError::ShapeMismatch { got: 127, want: 128 }), "{err:?}");
        assert_eq!(err.to_string(), "B rows 127 != matrix cols 128");
        // a good request still works afterwards
        let b = Dense::random(128, 8, &mut Rng::new(403));
        assert!(coord.call(id, b).is_ok());
        coord.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let (coord, _, _) = small_coordinator(EnginePolicy::Native);
        let err = coord.call(MatrixId(999), Dense::zeros(8, 8)).unwrap_err();
        assert!(matches!(err, ServeError::UnknownMatrix(MatrixId(999))), "{err:?}");
        coord.shutdown();
    }

    #[test]
    fn deadline_flush_serves_lone_requests() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                batch: BatchPolicy {
                    max_batch_cols: 4096,
                    max_batch_reqs: 1000,
                    max_delay: Duration::from_millis(1),
                },
                ..Default::default()
            },
            None,
        );
        let coo = Coo::random(32, 32, 0.2, &mut Rng::new(404));
        let id = coord.register("m", &coo);
        let b = Dense::random(32, 8, &mut Rng::new(405));
        let want = coo.to_dense().matmul(&b);
        let resp = coord.call(id, b).unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
        coord.shutdown();
    }

    #[test]
    fn metrics_accumulate() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        for i in 0..8 {
            let b = Dense::random(128, 8, &mut Rng::new(600 + i));
            coord.call(id, b).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.responses.load(Ordering::Relaxed), 8);
        assert_eq!(m.failures.load(Ordering::Relaxed), 0);
        assert!(m.request_latency.count() == 8);
        assert!(m.report().contains("responses=8"));
        coord.shutdown();
    }

    #[test]
    fn auto_policy_routes_by_synergy() {
        use crate::gen::{Family, MatrixSpec};
        use crate::synergy::Synergy;

        let coord = Coordinator::start(
            Config { workers: 2, engine: EnginePolicy::Auto, ..Default::default() },
            None,
        );
        assert!(coord.planner().is_some());

        // high synergy: dense-banded FEM regime (Emilia-like clustering)
        let high = MatrixSpec {
            name: "fem".into(),
            rows: 16_384,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.0 },
            seed: 7,
        }
        .generate();
        // low synergy: uniformly scattered (NotreDame-like)
        let low = Coo::random(4096, 4096, 8.0 / 4096.0, &mut Rng::new(8));

        let high_id = coord.register("high", &high);
        let low_id = coord.register("low", &low);

        let high_plan = coord.registry().get(high_id).unwrap().plan.clone().unwrap();
        let low_plan = coord.registry().get(low_id).unwrap().plan.clone().unwrap();
        assert_eq!(high_plan.synergy, Synergy::High, "alpha={}", high_plan.alpha);
        assert_eq!(high_plan.engine, Algo::Hrpb, "{}", high_plan.rationale);
        assert_eq!(low_plan.synergy, Synergy::Low, "alpha={}", low_plan.alpha);
        assert!(
            Algo::scalar_core().contains(&low_plan.engine),
            "low synergy chose {} ({})",
            low_plan.engine.name(),
            low_plan.rationale
        );

        // serve one request per matrix: results must match an independent
        // engine and the routing counters must attribute each batch to its
        // planned engine
        let mut rng = Rng::new(9);
        for (id, coo, plan_engine) in
            [(high_id, &high, high_plan.engine), (low_id, &low, low_plan.engine)]
        {
            let b = Dense::random(coo.cols, 8, &mut rng);
            let want = Algo::Csr.prepare(coo).spmm(&b);
            let resp = coord.call(id, b).unwrap();
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
            assert_eq!(resp.engine, plan_engine.name());
        }
        let m = coord.metrics();
        assert!(m.engine_requests(Algo::Hrpb) >= 1, "{}", m.report());
        assert!(m.engine_requests(low_plan.engine) >= 1, "{}", m.report());
        assert!(m.report().contains("routing="));
        coord.shutdown();
    }

    /// Auto registration of a structure-hiding row order activates the
    /// planner-gated reorder, mirrors the gains into the report, and still
    /// serves results in original row order.
    #[test]
    fn auto_registration_mirrors_reorder_gains_and_serves_in_original_order() {
        use crate::reorder::RowPermutation;
        let coord = Coordinator::start(
            Config { workers: 2, engine: EnginePolicy::Auto, ..Default::default() },
            None,
        );
        let spec = crate::gen::MatrixSpec {
            name: "hidden".into(),
            rows: 512,
            family: crate::gen::Family::BlockDiag { unit: 16, unit_density: 0.75 },
            seed: 0xAB5,
        };
        let base = spec.generate();
        let coo = RowPermutation::random(base.rows, &mut Rng::new(0xAB6)).apply_coo(&base);
        let id = coord.register("hidden", &coo);
        let e = coord.registry().get(id).unwrap();
        let gains = e.reorder.expect("hidden block structure must activate reordering");
        assert!(gains.alpha_after > gains.alpha_before);
        let report = coord.metrics().report();
        assert!(report.contains("reorder=[matrices=1"), "{report}");

        let b = Dense::random(coo.cols, 8, &mut Rng::new(0xAB7));
        let want = coo.to_dense().matmul(&b);
        let resp = coord.call(id, b).unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5, "rows come back in original order");
        coord.shutdown();
    }

    #[test]
    fn qos_sheds_when_saturated_with_typed_rejections() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                engine: EnginePolicy::Native,
                qos: Some(QosConfig {
                    queue_capacity: 2,
                    watermark_s: 0.0,
                    default_deadline: None,
                }),
                batch: BatchPolicy {
                    max_batch_cols: 8,
                    max_batch_reqs: 1,
                    max_delay: Duration::from_millis(0),
                },
                ..Default::default()
            },
            None,
        );
        let coo = crate::formats::Coo::random(1024, 1024, 0.05, &mut Rng::new(500));
        let id = coord.register("m", &coo);

        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..64u64 {
            let b = Dense::random(1024, 8, &mut Rng::new(600 + i));
            match coord.submit_qos(id, b, Priority::Normal, None) {
                Ok(rx) => accepted.push(rx),
                Err((err, returned_b)) => {
                    let ServeError::Shed(rejected) = &err else {
                        panic!("expected a typed shed, got {err:?}");
                    };
                    assert_eq!(rejected.reason, RejectReason::QueueFull);
                    assert_eq!(err.kind(), "shed");
                    assert!(err.to_string().starts_with("rejected"));
                    assert_eq!(returned_b.rows, 1024, "shed returns the operand");
                    shed += 1;
                }
            }
        }
        assert!(!accepted.is_empty());
        assert!(shed > 0, "a 2-deep queue under 64 rapid submits must shed");
        for rx in accepted {
            assert!(rx.recv().unwrap().is_ok(), "admitted requests complete");
        }
        let m = coord.metrics();
        assert_eq!(m.rejected.load(Ordering::Relaxed), shed);
        assert_eq!(m.qos[Priority::Normal.index()].shed_total(), shed);
        assert!(m.report().contains("qos=["), "{}", m.report());
        coord.shutdown();
    }

    #[test]
    fn qos_submit_with_converts_rejections_to_reply_errors() {
        let coord = Coordinator::start(
            Config {
                workers: 1,
                qos: Some(QosConfig {
                    queue_capacity: 1,
                    watermark_s: 0.0,
                    default_deadline: None,
                }),
                batch: BatchPolicy {
                    max_batch_cols: 8,
                    max_batch_reqs: 1,
                    max_delay: Duration::from_millis(0),
                },
                ..Default::default()
            },
            None,
        );
        let coo = crate::formats::Coo::random(512, 512, 0.05, &mut Rng::new(501));
        let id = coord.register("m", &coo);
        let mut rxs = Vec::new();
        for i in 0..32u64 {
            let b = Dense::random(512, 8, &mut Rng::new(700 + i));
            rxs.push(coord.submit_with(id, b, Priority::Normal, None));
        }
        let (mut ok, mut rejected) = (0, 0);
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert_eq!(e.kind(), "shed");
                    assert!(e.to_string().starts_with("rejected"), "{e}");
                    rejected += 1;
                }
            }
        }
        assert_eq!(ok + rejected, 32);
        assert!(ok >= 1);
        coord.shutdown();
    }

    #[test]
    fn artifact_dir_warm_starts_and_reports() {
        let dir = crate::hrpb::store::test_dir("coord_artifacts");
        let coo = Coo::random(128, 160, 0.06, &mut Rng::new(510));
        let want = {
            let b = Dense::random(160, 8, &mut Rng::new(511));
            (b.clone(), coo.to_dense().matmul(&b))
        };

        // cold process: builds, persists, reports a miss
        let cold = Coordinator::start(
            Config { workers: 2, artifact_dir: Some(dir.clone()), ..Default::default() },
            None,
        );
        let id = cold.register("m", &coo);
        assert!(cold.metrics().report().contains("artifacts=[hits=0 misses=1"));
        let resp = cold.call(id, want.0.clone()).unwrap();
        assert!(resp.c.rel_fro_error(&want.1) < 1e-5);
        cold.shutdown();

        // "restarted" process: same directory, registration is a hit and
        // serving is still correct
        let warm = Coordinator::start(
            Config { workers: 2, artifact_dir: Some(dir.clone()), ..Default::default() },
            None,
        );
        let id = warm.register("m", &coo);
        assert!(
            warm.metrics().report().contains("artifacts=[hits=1 misses=0"),
            "{}",
            warm.metrics().report()
        );
        let resp = warm.call(id, want.0).unwrap();
        assert!(resp.c.rel_fro_error(&want.1) < 1e-5);
        warm.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn engine_policy_parses() {
        assert_eq!(EnginePolicy::parse("native"), Some(EnginePolicy::Native));
        assert_eq!(EnginePolicy::parse("pjrt"), Some(EnginePolicy::PreferPjrt));
        assert_eq!(EnginePolicy::parse("AUTO"), Some(EnginePolicy::Auto));
        assert_eq!(EnginePolicy::parse("gpu"), None);
        assert_eq!(EnginePolicy::Auto.name(), "auto");
    }

    #[test]
    fn many_threads_hammering() {
        let coord = Arc::new(Coordinator::start(
            Config { workers: 4, ..Default::default() },
            None,
        ));
        let coo = Coo::random(128, 160, 0.04, &mut Rng::new(406));
        let id = coord.register("m", &coo);
        let dense = Arc::new(coo.to_dense());
        std::thread::scope(|s| {
            for t in 0..8 {
                let coord = coord.clone();
                let dense = dense.clone();
                s.spawn(move || {
                    for i in 0..5 {
                        let b = Dense::random(160, 8, &mut Rng::new(t * 100 + i));
                        let want = dense.matmul(&b);
                        let resp = coord.call(id, b).unwrap();
                        assert!(resp.c.rel_fro_error(&want) < 1e-5);
                    }
                });
            }
        });
        assert_eq!(coord.metrics().responses.load(Ordering::Relaxed), 40);
    }

    /// Satellite: a shutdown racing a submission surfaces as a typed
    /// `ServeError::Shutdown` on every submit shape — never a panic.
    #[test]
    fn submits_after_shutdown_return_the_typed_error_not_a_panic() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        coord.drain();
        let err = coord.call(id, Dense::zeros(128, 4)).unwrap_err();
        assert!(matches!(err, ServeError::Shutdown), "{err:?}");
        assert_eq!(err.to_string(), "coordinator stopped");
        match coord.try_submit(id, Dense::zeros(128, 4)) {
            Err((ServeError::Shutdown, b)) => assert_eq!(b.rows, 128, "operand comes back"),
            other => panic!("expected a typed shutdown, got {other:?}"),
        }
    }

    /// PR 10: `drain` works by shared reference (the network server holds
    /// the coordinator in an `Arc`) and is idempotent — a second drain and
    /// the eventual `Drop` are no-ops, not double-joins.
    #[test]
    fn drain_works_through_an_arc_and_is_idempotent() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        let coord = Arc::new(coord);
        let b = Dense::random(128, 4, &mut Rng::new(407));
        assert!(coord.call(id, b).is_ok());
        coord.drain();
        coord.drain();
        let err = coord.call(id, Dense::zeros(128, 4)).unwrap_err();
        assert!(matches!(err, ServeError::Shutdown), "{err:?}");
    }

    /// Satellite: `submit_qos` without `Config::qos` is a typed
    /// `Misconfigured`, and the coordinator survives the misuse.
    #[test]
    fn submit_qos_without_qos_config_is_misconfigured_not_fatal() {
        let (coord, id, _) = small_coordinator(EnginePolicy::Native);
        let b = Dense::random(128, 8, &mut Rng::new(900));
        match coord.submit_qos(id, b, Priority::High, None) {
            Err((e, returned)) => {
                assert!(matches!(e, ServeError::Misconfigured(_)), "{e:?}");
                assert_eq!(e.kind(), "misconfigured");
                assert_eq!(returned.rows, 128, "the operand comes back");
            }
            Ok(_) => panic!("must not admit without Config::qos"),
        }
        // ... and the properly configured path still admits (the other
        // half of "test both paths" rides the qos tests above)
        let b = Dense::random(128, 8, &mut Rng::new(901));
        assert!(coord.call(id, b).is_ok(), "the coordinator survives the misuse");
        coord.shutdown();
    }

    /// RAII disarm for fault-injection tests: the global plan must clear
    /// even when an assertion unwinds mid-test.
    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            fault::disable();
        }
    }

    fn one_req_batches() -> BatchPolicy {
        BatchPolicy { max_batch_cols: 8, max_batch_reqs: 1, max_delay: Duration::from_millis(0) }
    }

    /// Acceptance: an injected kernel panic on one matrix fails only that
    /// matrix's requests with a typed `EngineFault`, flips its breaker to
    /// the CSR fallback within K faults, and never touches the clean
    /// matrix or the worker pool.
    #[test]
    fn injected_kernel_panics_are_contained_and_flip_the_breaker() {
        let _s = fault::session_guard();
        let _d = Disarm;
        let coord = Coordinator::start(
            Config {
                workers: 2,
                engine: EnginePolicy::Native,
                batch: one_req_batches(),
                ..Default::default()
            },
            None,
        );
        let victim = Coo::random(96, 128, 0.05, &mut Rng::new(430));
        let clean = Coo::random(96, 128, 0.05, &mut Rng::new(431));
        let vid = coord.register("victim", &victim);
        let cid = coord.register("clean", &clean);
        let clean_dense = clean.to_dense();
        // engine-qualified target: only the primary engine's dispatches
        // for the victim fault — the CSR fallback path stays healthy
        fault::install(&fault::FaultPlan::parse("kernel_panic@cutespmm@victim", 5).unwrap());

        for i in 0..breaker::FAULT_THRESHOLD as u64 {
            let b = Dense::random(128, 8, &mut Rng::new(910 + i));
            match coord.call(vid, b) {
                Err(ServeError::EngineFault { matrix, engine, detail }) => {
                    assert_eq!(matrix, "victim");
                    assert_eq!(engine, "cutespmm-native");
                    assert!(detail.contains("injected kernel fault"), "{detail}");
                }
                other => panic!("expected exactly one contained fault, got {other:?}"),
            }
            // the clean matrix keeps serving correct results in between
            let b = Dense::random(128, 8, &mut Rng::new(920 + i));
            let want = clean_dense.matmul(&b);
            let resp = coord.call(cid, b).expect("clean matrix must be isolated");
            assert!(resp.c.rel_fro_error(&want) < 1e-5);
        }
        let entry = coord.registry().get(vid).unwrap();
        assert_eq!(entry.breaker.state(), BreakerState::Open, "K faults must open the breaker");

        // open breaker: the victim reroutes to the CSR fallback and serves
        // correct results again while the fault is still armed
        let b = Dense::random(128, 8, &mut Rng::new(930));
        let want = victim.to_dense().matmul(&b);
        let resp = coord.call(vid, b).expect("fallback must serve under an open breaker");
        assert_eq!(resp.engine, "csr");
        assert!(resp.c.rel_fro_error(&want) < 1e-5);

        fault::disable();
        let snap = coord.metrics().snapshot();
        assert!(snap.faults.engine_faults >= breaker::FAULT_THRESHOLD as u64);
        assert!(snap.faults.opens >= 1, "the open transition lands in metrics");
        assert!(snap.faults.fallback_requests >= 1);
        assert!(snap.faults.injected >= breaker::FAULT_THRESHOLD as u64);
        let report = coord.metrics().report();
        assert!(report.contains("faults=["), "{report}");
        assert!(report.contains("breakers=[victim:open"), "{report}");
        coord.shutdown();
    }

    /// A matrix that faults even on the CSR fallback is quarantined with a
    /// typed rejection; the pool survives and other matrices still serve.
    #[test]
    fn faults_on_the_fallback_quarantine_the_matrix() {
        let _s = fault::session_guard();
        let _d = Disarm;
        let coord = Coordinator::start(
            Config {
                workers: 1,
                engine: EnginePolicy::Native,
                batch: one_req_batches(),
                ..Default::default()
            },
            None,
        );
        let victim = Coo::random(64, 64, 0.1, &mut Rng::new(440));
        let clean = Coo::random(64, 64, 0.1, &mut Rng::new(441));
        let vid = coord.register("victim", &victim);
        let cid = coord.register("clean", &clean);
        // matrix-wide target: the panic follows the victim onto the
        // fallback engine too (key "csr@victim" also matches)
        fault::install(&fault::FaultPlan::parse("kernel_panic@victim", 6).unwrap());

        // K primary faults open the breaker, then K fallback faults
        // quarantine — every one of them a typed EngineFault
        for i in 0..(2 * breaker::FAULT_THRESHOLD) as u64 {
            let err = coord.call(vid, Dense::random(64, 8, &mut Rng::new(950 + i))).unwrap_err();
            assert!(err.is_fault(), "fault {i}: {err:?}");
        }
        let entry = coord.registry().get(vid).unwrap();
        assert_eq!(entry.breaker.state(), BreakerState::Quarantined);

        // quarantine is a typed, sticky rejection — no engine dispatch
        let err = coord.call(vid, Dense::random(64, 8, &mut Rng::new(960))).unwrap_err();
        assert!(matches!(err, ServeError::Quarantined { .. }), "{err:?}");
        assert!(err.to_string().contains("quarantined"));

        // the worker survived 2K contained panics; clean traffic still flows
        fault::disable();
        let b = Dense::random(64, 8, &mut Rng::new(961));
        let want = clean.to_dense().matmul(&b);
        let resp = coord.call(cid, b).expect("pool must survive contained faults");
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
        let snap = coord.metrics().snapshot();
        assert!(snap.faults.quarantined >= 1);
        assert!(coord.metrics().report().contains("breakers=[victim:quarantined"));
        coord.shutdown();
    }
}
