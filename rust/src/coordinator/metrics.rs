//! Serving metrics: latency histograms, throughput counters, queue gauges,
//! and per-engine routing lanes (which engine served what, and how far the
//! observed latency drifts from the planner's prediction).

use crate::spmm::Algo;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log-2 bucketed latency histogram, microsecond resolution, thread-safe.
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^{i+1}) µs; 32 buckets = up to ~1h
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile from the log-2 buckets (upper bound of the
    /// bucket containing the p-quantile).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= want {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }

    /// (bucket upper bound µs, count) pairs for display.
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| (1u64 << (i + 1), b.load(Ordering::Relaxed)))
            .filter(|&(_, c)| c > 0)
            .collect()
    }
}

/// Routing lanes: one per executable algorithm plus one for the PJRT
/// artifact engine.
pub const ENGINE_LANES: usize = Algo::COUNT + 1;

/// Lane index of the PJRT engine (algorithm lanes use [`Algo::index`]).
pub const PJRT_LANE: usize = Algo::COUNT;

/// Display name of a routing lane.
pub fn lane_name(lane: usize) -> &'static str {
    if lane == PJRT_LANE {
        return "pjrt";
    }
    Algo::all()
        .into_iter()
        .find(|a| a.index() == lane)
        .map(|a| a.name())
        .unwrap_or("?")
}

/// Per-engine routing counters and observed-vs-predicted latency gauges.
#[derive(Default)]
pub struct EngineLane {
    /// Requests served by this engine.
    pub requests: AtomicU64,
    /// Batches executed by this engine.
    pub batches: AtomicU64,
    /// Total observed execution time (µs) across batches.
    pub observed_us: AtomicU64,
    /// Total planner-predicted time (µs) for the same batches (0 when the
    /// route had no plan, e.g. fixed policies).
    pub predicted_us: AtomicU64,
}

/// Snapshot of one routing lane.
#[derive(Clone, Copy, Debug)]
pub struct EngineLaneSnapshot {
    pub engine: &'static str,
    pub requests: u64,
    pub batches: u64,
    pub observed_us: u64,
    pub predicted_us: u64,
    /// observed/predicted across all batches; 1.0 = model exact, 0.0 = no
    /// prediction recorded.
    pub drift: f64,
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    /// End-to-end request latency (submit → response).
    pub request_latency: LatencyHistogram,
    /// Kernel execution latency per batch.
    pub exec_latency: LatencyHistogram,
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    /// Requests folded together across all batches (batching efficiency =
    /// batched / batches).
    pub batched_requests: AtomicU64,
    pub rejected: AtomicU64,
    pub queue_depth: AtomicUsize,
    /// FLOPs served (useful, 2·nnz·n per request).
    pub flops: Mutex<f64>,
    /// Per-engine routing lanes ([`Algo::index`] + [`PJRT_LANE`]).
    pub engines: [EngineLane; ENGINE_LANES],
}

impl Metrics {
    pub fn add_flops(&self, f: f64) {
        *self.flops.lock().unwrap() += f;
    }

    /// Record one executed batch on a routing lane. `predicted_s` is the
    /// planner's corrected prediction for this batch (0.0 when unplanned).
    pub fn record_route(&self, lane: usize, requests: u64, observed: Duration, predicted_s: f64) {
        let l = &self.engines[lane];
        l.requests.fetch_add(requests, Ordering::Relaxed);
        l.batches.fetch_add(1, Ordering::Relaxed);
        l.observed_us.fetch_add(observed.as_micros().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        if predicted_s > 0.0 {
            l.predicted_us.fetch_add((predicted_s * 1e6) as u64, Ordering::Relaxed);
        }
    }

    /// Requests served by `algo`'s lane (test + report convenience).
    pub fn engine_requests(&self, algo: Algo) -> u64 {
        self.engines[algo.index()].requests.load(Ordering::Relaxed)
    }

    /// Snapshot of every lane that served at least one batch.
    pub fn engine_snapshot(&self) -> Vec<EngineLaneSnapshot> {
        (0..ENGINE_LANES)
            .filter_map(|i| {
                let l = &self.engines[i];
                let batches = l.batches.load(Ordering::Relaxed);
                if batches == 0 {
                    return None;
                }
                let observed_us = l.observed_us.load(Ordering::Relaxed);
                let predicted_us = l.predicted_us.load(Ordering::Relaxed);
                Some(EngineLaneSnapshot {
                    engine: lane_name(i),
                    requests: l.requests.load(Ordering::Relaxed),
                    batches,
                    observed_us,
                    predicted_us,
                    drift: if predicted_us > 0 {
                        observed_us as f64 / predicted_us as f64
                    } else {
                        0.0
                    },
                })
            })
            .collect()
    }

    pub fn report(&self) -> String {
        let lat = &self.request_latency;
        let mut out = format!(
            "requests={} responses={} failures={} rejected={} batches={} \
             avg_batch={:.2} latency(mean/p50/p95/p99/max µs)={:.0}/{}/{}/{}/{} \
             served_gflop={:.3}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed) as f64
                / self.batches.load(Ordering::Relaxed).max(1) as f64,
            lat.mean_us(),
            lat.percentile_us(50.0),
            lat.percentile_us(95.0),
            lat.percentile_us(99.0),
            lat.max_us(),
            *self.flops.lock().unwrap() / 1e9,
        );
        let lanes = self.engine_snapshot();
        if !lanes.is_empty() {
            out.push_str(" routing=[");
            for (i, l) in lanes.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                if l.predicted_us > 0 {
                    out.push_str(&format!("{}:{}(drift={:.2}x)", l.engine, l.requests, l.drift));
                } else {
                    out.push_str(&format!("{}:{}", l.engine, l.requests));
                }
            }
            out.push(']');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1000, 5000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        assert!(h.percentile_us(95.0) <= h.percentile_us(99.9).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_only_nonempty() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(100));
        let snap = h.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, 2);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.add_flops(1e9);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(r.contains("served_gflop=1.000"));
        assert!(!r.contains("routing="), "no lanes used -> no routing section");
    }

    #[test]
    fn routing_lanes_accumulate_and_report() {
        let m = Metrics::default();
        m.record_route(Algo::Hrpb.index(), 4, Duration::from_micros(200), 100e-6);
        m.record_route(Algo::Hrpb.index(), 2, Duration::from_micros(200), 100e-6);
        m.record_route(Algo::Sputnik.index(), 1, Duration::from_micros(50), 0.0);
        assert_eq!(m.engine_requests(Algo::Hrpb), 6);
        assert_eq!(m.engine_requests(Algo::Sputnik), 1);
        assert_eq!(m.engine_requests(Algo::Csr), 0);

        let snap = m.engine_snapshot();
        assert_eq!(snap.len(), 2);
        let hrpb = snap.iter().find(|l| l.engine == "cutespmm").unwrap();
        assert_eq!(hrpb.batches, 2);
        assert_eq!(hrpb.observed_us, 400);
        assert_eq!(hrpb.predicted_us, 200);
        assert!((hrpb.drift - 2.0).abs() < 1e-9, "drift {}", hrpb.drift);
        let sput = snap.iter().find(|l| l.engine == "sputnik").unwrap();
        assert_eq!(sput.drift, 0.0, "no prediction -> no drift gauge");

        let r = m.report();
        assert!(r.contains("routing="), "{r}");
        assert!(r.contains("cutespmm:6(drift=2.00x)"), "{r}");
        assert!(r.contains("sputnik:1"), "{r}");
    }

    #[test]
    fn lane_names_cover_all_lanes() {
        for lane in 0..ENGINE_LANES {
            assert_ne!(lane_name(lane), "?", "lane {lane}");
        }
        assert_eq!(lane_name(PJRT_LANE), "pjrt");
        assert_eq!(lane_name(Algo::Hrpb.index()), "cutespmm");
    }
}
