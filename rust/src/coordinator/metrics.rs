//! Serving metrics: latency histograms, throughput counters, queue gauges,
//! per-engine routing lanes (which engine served what, and how far the
//! observed latency drifts from the planner's prediction), and per-lane QoS
//! admission counters (admitted / shed-by-reason / depth / queue wait).
//!
//! Export model: [`Metrics::snapshot`] produces a [`MetricsSnapshot`] — the
//! structured, machine-readable view (full histogram buckets, p999,
//! per-lane QoS, artifact/arena/reorder sections) with a
//! [`MetricsSnapshot::to_json`] serialization for scrapers
//! (`cutespmm metrics`, `serve --metrics-out`). The human-readable
//! [`Metrics::report`] string is *rendered from* that snapshot, so every
//! report field has a structured source of truth.

use crate::qos::{Priority, RejectReason};
use crate::spmm::Algo;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log-2 bucketed latency histogram, microsecond resolution, thread-safe.
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^{i+1}) µs; 32 buckets = up to ~1h
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile from the log-2 buckets, linearly interpolated
    /// within the bucket containing the p-quantile (midpoint rank
    /// convention: a single-sample bucket reports the bucket *center*).
    /// The old implementation returned the bucket's upper bound, which
    /// overstated p50 by up to 2×. Clamped to the observed maximum.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = (((p / 100.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 && seen + c >= want {
                let lo = (1u64 << i) as f64;
                let hi = (1u64 << (i + 1)) as f64;
                let frac = ((want - seen) as f64 - 0.5) / c as f64;
                return ((lo + frac * (hi - lo)).round() as u64).min(self.max_us());
            }
            seen += c;
        }
        self.max_us()
    }

    /// (bucket upper bound µs, count) pairs for display.
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| (1u64 << (i + 1), b.load(Ordering::Relaxed)))
            .filter(|&(_, c)| c > 0)
            .collect()
    }

    /// Full structured view: summary statistics, tail percentiles
    /// (including p999), and every non-empty bucket with its bounds.
    pub fn summarize(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            mean_us: self.mean_us(),
            max_us: self.max_us(),
            p50_us: self.percentile_us(50.0),
            p95_us: self.percentile_us(95.0),
            p99_us: self.percentile_us(99.0),
            p999_us: self.percentile_us(99.9),
            buckets: self
                .buckets
                .iter()
                .enumerate()
                .map(|(i, b)| (1u64 << i, 1u64 << (i + 1), b.load(Ordering::Relaxed)))
                .filter(|&(_, _, c)| c > 0)
                .collect(),
        }
    }
}

/// Point-in-time structured view of a [`LatencyHistogram`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub max_us: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    /// Non-empty log-2 buckets as (lower bound µs, upper bound µs, count).
    pub buckets: Vec<(u64, u64, u64)>,
}

impl HistogramSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_us", Json::num(self.mean_us)),
            ("max_us", Json::num(self.max_us as f64)),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p95_us", Json::num(self.p95_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
            ("p999_us", Json::num(self.p999_us as f64)),
            (
                "buckets",
                Json::arr(self.buckets.iter().map(|&(lo, hi, c)| {
                    Json::obj(vec![
                        ("lo_us", Json::num(lo as f64)),
                        ("hi_us", Json::num(hi as f64)),
                        ("count", Json::num(c as f64)),
                    ])
                })),
            ),
        ])
    }
}

/// Routing lanes: one per executable algorithm plus one for the PJRT
/// artifact engine.
pub const ENGINE_LANES: usize = Algo::COUNT + 1;

/// Lane index of the PJRT engine (algorithm lanes use [`Algo::index`]).
pub const PJRT_LANE: usize = Algo::COUNT;

/// Display name of a routing lane.
pub fn lane_name(lane: usize) -> &'static str {
    if lane == PJRT_LANE {
        return "pjrt";
    }
    Algo::all()
        .into_iter()
        .find(|a| a.index() == lane)
        .map(|a| a.name())
        .unwrap_or("?")
}

/// Per-engine routing counters and observed-vs-predicted latency gauges.
#[derive(Default)]
pub struct EngineLane {
    /// Requests served by this engine.
    pub requests: AtomicU64,
    /// Batches executed by this engine.
    pub batches: AtomicU64,
    /// Total observed execution time (µs) across batches.
    pub observed_us: AtomicU64,
    /// Total planner-predicted time (µs) for the same batches (0 when the
    /// route had no plan, e.g. fixed policies).
    pub predicted_us: AtomicU64,
}

/// Snapshot of one routing lane.
#[derive(Clone, Copy, Debug)]
pub struct EngineLaneSnapshot {
    pub engine: &'static str,
    pub requests: u64,
    pub batches: u64,
    pub observed_us: u64,
    pub predicted_us: u64,
    /// observed/predicted across all batches; 1.0 = model exact, 0.0 = no
    /// prediction recorded.
    pub drift: f64,
}

/// Per-lane QoS admission counters (indexed by [`Priority::index`]).
#[derive(Default)]
pub struct QosLane {
    /// Requests admitted into this lane.
    pub admitted: AtomicU64,
    /// Requests shed at admission, by [`RejectReason::index`].
    pub shed: [AtomicU64; RejectReason::COUNT],
    /// Queue depth gauge (mirrored from the admission queue).
    pub depth: AtomicUsize,
    /// Admission → drain wait.
    pub queue_wait: LatencyHistogram,
}

impl QosLane {
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Aggregate row-reorder gains mirrored from the registry at registration
/// time (absolute snapshot, like the artifact counters: the registry owns
/// the truth, the report displays it).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReorderSnapshot {
    /// Entries serving through a similarity-clustered permutation.
    pub matrices: u64,
    /// Sums over those entries (the report prints the means).
    pub alpha_before: f64,
    pub alpha_after: f64,
    pub beta_before: f64,
    pub beta_after: f64,
    /// Total one-time reorder preprocessing seconds.
    pub seconds: f64,
}

impl ReorderSnapshot {
    /// Fold one entry's gains into the aggregate.
    pub fn add(&mut self, g: crate::reorder::Gains) {
        self.matrices += 1;
        self.alpha_before += g.alpha_before;
        self.alpha_after += g.alpha_after;
        self.beta_before += g.beta_before;
        self.beta_after += g.beta_after;
        self.seconds += g.seconds;
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    /// End-to-end request latency (submit → response).
    pub request_latency: LatencyHistogram,
    /// Kernel execution latency per batch.
    pub exec_latency: LatencyHistogram,
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    /// Requests folded together across all batches (batching efficiency =
    /// batched / batches).
    pub batched_requests: AtomicU64,
    pub rejected: AtomicU64,
    pub queue_depth: AtomicUsize,
    /// FLOPs served (useful, 2·nnz·n per request), stored as `f64` bits and
    /// accumulated with a CAS loop — this was the only lock taken on the
    /// per-request hot path. Read through [`Metrics::flops`].
    pub flops: AtomicU64,
    /// Per-engine routing lanes ([`Algo::index`] + [`PJRT_LANE`]).
    pub engines: [EngineLane; ENGINE_LANES],
    /// QoS admission lanes ([`Priority::index`]); silent until the
    /// admission layer is enabled.
    pub qos: [QosLane; Priority::COUNT],
    /// Predicted cost (µs) of QoS-admitted work already drained out of the
    /// admission queue but not yet completed (batcher + job channel +
    /// executing). Added on router pop, subtracted when the worker replies,
    /// so the admission estimator sees the whole pipeline, not just the
    /// queue.
    pub qos_downstream_cost_us: AtomicU64,
    /// HRPB artifact store counters, mirrored from the registry's
    /// [`crate::hrpb::ArtifactStore`] at registration time; silent until an
    /// artifact directory is configured.
    pub artifact_hits: AtomicU64,
    pub artifact_misses: AtomicU64,
    pub artifact_invalidated: AtomicU64,
    /// Output-buffer arena counters, mirrored from the workers' shared
    /// [`crate::spmm::exec::OutputArena`] after each batch: in steady state
    /// `arena_misses` stops moving (zero output allocations per batch).
    pub arena_hits: AtomicU64,
    pub arena_misses: AtomicU64,
    /// Row-reorder gains mirrored from the registry entries at
    /// registration time; silent until a planner-gated permutation
    /// activates.
    pub reorder: Mutex<ReorderSnapshot>,
    /// Trace-ring totals mirrored from [`crate::trace::ring_totals`] after
    /// each batch when tracing is on: session-lifetime spans recorded and
    /// spans lost to ring overflow. Silent until a span records — their
    /// visibility is what makes silent span loss observable.
    pub trace_spans_recorded: AtomicU64,
    pub trace_spans_dropped: AtomicU64,
    /// Fault-containment counters: engine/kernel panics contained at the
    /// dispatch boundary ([`crate::coordinator::ServeError::EngineFault`]
    /// replies), requests served on the CSR fallback while a breaker was
    /// open, and requests rejected because a matrix is quarantined. All
    /// zero until something faults.
    pub engine_faults: AtomicU64,
    pub fallback_requests: AtomicU64,
    pub quarantined_rejects: AtomicU64,
    /// Aggregate breaker transition counters mirrored from the registry
    /// entries after each non-primary batch (absolute snapshot — the
    /// per-matrix [`super::breaker::Breaker`]s own the counts).
    breaker_opens: AtomicU64,
    breaker_closes: AtomicU64,
    breaker_probes: AtomicU64,
    /// Faults fired by the deterministic injection facility
    /// ([`crate::fault`]), mirrored absolute; nonzero only under a chaos
    /// session.
    injected_faults: AtomicU64,
    /// Non-closed per-matrix breaker states mirrored from the registry;
    /// empty (and silent in the report) while every breaker is closed.
    breakers: Mutex<Vec<BreakerEntry>>,
}

/// One non-closed breaker in a [`MetricsSnapshot`]: which matrix and the
/// state name from [`crate::coordinator::BreakerState::name`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BreakerEntry {
    pub matrix: String,
    pub state: &'static str,
}

/// Predicted-cost seconds → the µs unit the downstream gauge accumulates.
/// Add and subtract sites convert from the *same* stored `f64`, so paired
/// updates cancel exactly and the gauge can never underflow.
fn qos_cost_us(cost_s: f64) -> u64 {
    (cost_s.max(0.0) * 1e6) as u64
}

impl Metrics {
    /// Accumulate served FLOPs lock-free: a compare-exchange loop over the
    /// f64 bit pattern (contention is rare — one update per reply — so the
    /// loop almost always succeeds on the first attempt).
    pub fn add_flops(&self, f: f64) {
        let mut cur = self.flops.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + f).to_bits();
            match self.flops.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total FLOPs served.
    pub fn flops(&self) -> f64 {
        f64::from_bits(self.flops.load(Ordering::Relaxed))
    }

    /// Record one executed batch on a routing lane. `predicted_s` is the
    /// planner's corrected prediction for this batch (0.0 when unplanned).
    pub fn record_route(&self, lane: usize, requests: u64, observed: Duration, predicted_s: f64) {
        let l = &self.engines[lane];
        l.requests.fetch_add(requests, Ordering::Relaxed);
        l.batches.fetch_add(1, Ordering::Relaxed);
        l.observed_us.fetch_add(observed.as_micros().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        if predicted_s > 0.0 {
            l.predicted_us.fetch_add((predicted_s * 1e6) as u64, Ordering::Relaxed);
        }
    }

    /// Record one admitted request on a QoS lane.
    pub fn record_admitted(&self, p: Priority) {
        self.qos[p.index()].admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one shed request on a QoS lane.
    pub fn record_shed(&self, p: Priority, reason: RejectReason) {
        self.qos[p.index()].shed[reason.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one admission → drain wait on a QoS lane.
    pub fn record_queue_wait(&self, p: Priority, wait: Duration) {
        self.qos[p.index()].queue_wait.record(wait);
    }

    /// Mirror the admission queue's lane depth gauge.
    pub fn set_qos_depth(&self, p: Priority, depth: usize) {
        self.qos[p.index()].depth.store(depth, Ordering::Relaxed);
    }

    /// Requests shed at admission across all lanes and reasons.
    pub fn shed_total(&self) -> u64 {
        self.qos.iter().map(|l| l.shed_total()).sum()
    }

    /// Account predicted cost leaving the admission queue for the batcher.
    pub fn add_qos_downstream(&self, cost_s: f64) {
        self.qos_downstream_cost_us.fetch_add(qos_cost_us(cost_s), Ordering::Relaxed);
    }

    /// Account predicted cost leaving the pipeline (reply sent or request
    /// failed). Must mirror a prior [`Metrics::add_qos_downstream`] with the
    /// same stored cost.
    pub fn sub_qos_downstream(&self, cost_s: f64) {
        self.qos_downstream_cost_us.fetch_sub(qos_cost_us(cost_s), Ordering::Relaxed);
    }

    /// Predicted cost (seconds) drained from the admission queue but not yet
    /// completed — the admission estimator's view of downstream backlog.
    pub fn qos_downstream_cost_s(&self) -> f64 {
        self.qos_downstream_cost_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Mirror the artifact store's counter snapshot (absolute values — the
    /// store owns the counts, the metrics report only displays them).
    pub fn sync_artifacts(&self, s: crate::hrpb::StoreStats) {
        self.artifact_hits.store(s.hits, Ordering::Relaxed);
        self.artifact_misses.store(s.misses, Ordering::Relaxed);
        self.artifact_invalidated.store(s.invalidated, Ordering::Relaxed);
    }

    /// Mirror the output-buffer arena's counter snapshot (absolute values —
    /// the arena owns the counts, the report only displays them).
    pub fn sync_arena(&self, hits: u64, misses: u64) {
        self.arena_hits.store(hits, Ordering::Relaxed);
        self.arena_misses.store(misses, Ordering::Relaxed);
    }

    /// Mirror the registry's aggregate reorder gains (absolute snapshot).
    pub fn sync_reorder(&self, s: ReorderSnapshot) {
        *self.reorder.lock().unwrap() = s;
    }

    /// Mirror the trace rings' monotonic recorded/dropped totals (absolute
    /// values — the rings own the counts, the report only displays them).
    pub fn sync_trace(&self, recorded: u64, dropped: u64) {
        self.trace_spans_recorded.store(recorded, Ordering::Relaxed);
        self.trace_spans_dropped.store(dropped, Ordering::Relaxed);
    }

    /// Mirror the registry's breaker view: non-closed per-matrix states
    /// plus the aggregate transition counters (absolute snapshot — the
    /// breakers own the counts, the report only displays them).
    pub fn sync_breakers(&self, snap: Vec<BreakerEntry>, totals: super::breaker::BreakerCounters) {
        self.breaker_opens.store(totals.opens, Ordering::Relaxed);
        self.breaker_closes.store(totals.closes, Ordering::Relaxed);
        self.breaker_probes.store(totals.probes, Ordering::Relaxed);
        *self.breakers.lock().unwrap_or_else(|p| p.into_inner()) = snap;
    }

    /// Mirror the fault-injection facility's session-lifetime fire count
    /// ([`crate::fault::fired_total`]).
    pub fn sync_injected(&self, n: u64) {
        self.injected_faults.store(n, Ordering::Relaxed);
    }

    /// Requests served by `algo`'s lane (test + report convenience).
    pub fn engine_requests(&self, algo: Algo) -> u64 {
        self.engines[algo.index()].requests.load(Ordering::Relaxed)
    }

    /// Snapshot of every lane that served at least one batch.
    pub fn engine_snapshot(&self) -> Vec<EngineLaneSnapshot> {
        (0..ENGINE_LANES)
            .filter_map(|i| {
                let l = &self.engines[i];
                let batches = l.batches.load(Ordering::Relaxed);
                if batches == 0 {
                    return None;
                }
                let observed_us = l.observed_us.load(Ordering::Relaxed);
                let predicted_us = l.predicted_us.load(Ordering::Relaxed);
                Some(EngineLaneSnapshot {
                    engine: lane_name(i),
                    requests: l.requests.load(Ordering::Relaxed),
                    batches,
                    observed_us,
                    predicted_us,
                    drift: if predicted_us > 0 {
                        observed_us as f64 / predicted_us as f64
                    } else {
                        0.0
                    },
                })
            })
            .collect()
    }

    /// Capture the full structured snapshot: every counter, both latency
    /// histograms with buckets and tail percentiles, routing lanes,
    /// artifact/arena/reorder mirrors, and (when active) per-lane QoS.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let qos_active = self
            .qos
            .iter()
            .any(|l| l.admitted.load(Ordering::Relaxed) > 0 || l.shed_total() > 0);
        let qos = qos_active.then(|| {
            Priority::all()
                .into_iter()
                .map(|p| {
                    let l = &self.qos[p.index()];
                    QosLaneSnapshot {
                        lane: p.name(),
                        admitted: l.admitted.load(Ordering::Relaxed),
                        depth: l.depth.load(Ordering::Relaxed),
                        shed: RejectReason::all()
                            .into_iter()
                            .map(|r| (r.name(), l.shed[r.index()].load(Ordering::Relaxed)))
                            .collect(),
                        queue_wait: l.queue_wait.summarize(),
                    }
                })
                .collect()
        });
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            avg_batch: self.batched_requests.load(Ordering::Relaxed) as f64
                / batches.max(1) as f64,
            request_latency: self.request_latency.summarize(),
            exec_latency: self.exec_latency.summarize(),
            served_gflop: self.flops() / 1e9,
            engines: self.engine_snapshot(),
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            artifact_misses: self.artifact_misses.load(Ordering::Relaxed),
            artifact_invalidated: self.artifact_invalidated.load(Ordering::Relaxed),
            arena_hits: self.arena_hits.load(Ordering::Relaxed),
            arena_misses: self.arena_misses.load(Ordering::Relaxed),
            reorder: *self.reorder.lock().unwrap(),
            qos,
            qos_downstream_cost_s: self.qos_downstream_cost_s(),
            trace_spans_recorded: self.trace_spans_recorded.load(Ordering::Relaxed),
            trace_spans_dropped: self.trace_spans_dropped.load(Ordering::Relaxed),
            faults: FaultsSnapshot {
                engine_faults: self.engine_faults.load(Ordering::Relaxed),
                fallback_requests: self.fallback_requests.load(Ordering::Relaxed),
                quarantined: self.quarantined_rejects.load(Ordering::Relaxed),
                opens: self.breaker_opens.load(Ordering::Relaxed),
                closes: self.breaker_closes.load(Ordering::Relaxed),
                probes: self.breaker_probes.load(Ordering::Relaxed),
                injected: self.injected_faults.load(Ordering::Relaxed),
            },
            breakers: self.breakers.lock().unwrap_or_else(|p| p.into_inner()).clone(),
        }
    }

    /// Human-readable one-line report, rendered from [`Metrics::snapshot`]
    /// so every field here has a structured, scrapable source of truth.
    pub fn report(&self) -> String {
        self.snapshot().render()
    }
}

/// One QoS admission lane in a [`MetricsSnapshot`].
#[derive(Clone, Debug)]
pub struct QosLaneSnapshot {
    pub lane: &'static str,
    pub admitted: u64,
    pub depth: usize,
    /// Shed counts per [`RejectReason`], *all* reasons including zeros —
    /// scrapers should not need the enum to see a zero.
    pub shed: Vec<(&'static str, u64)>,
    pub queue_wait: HistogramSnapshot,
}

/// Fault-containment counters in a [`MetricsSnapshot`]: contained panics,
/// fallback serves, quarantine rejections, breaker transitions, and
/// injected (chaos) faults.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultsSnapshot {
    pub engine_faults: u64,
    pub fallback_requests: u64,
    pub quarantined: u64,
    pub opens: u64,
    pub closes: u64,
    pub probes: u64,
    pub injected: u64,
}

impl FaultsSnapshot {
    /// Did anything fault-related happen? Gates the report section.
    pub fn any(&self) -> bool {
        self.engine_faults
            + self.fallback_requests
            + self.quarantined
            + self.opens
            + self.closes
            + self.probes
            + self.injected
            > 0
    }
}

/// Structured point-in-time export of every serving metric — the
/// machine-readable replacement for string-grepping [`Metrics::report`]
/// (which is rendered from this snapshot).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub failures: u64,
    pub rejected: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub queue_depth: usize,
    pub avg_batch: f64,
    pub request_latency: HistogramSnapshot,
    pub exec_latency: HistogramSnapshot,
    pub served_gflop: f64,
    /// Routing lanes that served at least one batch.
    pub engines: Vec<EngineLaneSnapshot>,
    pub artifact_hits: u64,
    pub artifact_misses: u64,
    pub artifact_invalidated: u64,
    pub arena_hits: u64,
    pub arena_misses: u64,
    pub reorder: ReorderSnapshot,
    /// Per-priority admission lanes; `None` until the QoS layer saw
    /// activity (keeps the report section silent, as before).
    pub qos: Option<Vec<QosLaneSnapshot>>,
    pub qos_downstream_cost_s: f64,
    /// Session-lifetime trace-ring totals (spans recorded / spans lost to
    /// ring overflow); both zero until a trace session records.
    pub trace_spans_recorded: u64,
    pub trace_spans_dropped: u64,
    /// Fault-containment counters; all zero (and the report section
    /// silent) until a fault occurs.
    pub faults: FaultsSnapshot,
    /// Non-closed per-matrix breaker states; empty while every breaker is
    /// closed.
    pub breakers: Vec<BreakerEntry>,
}

impl MetricsSnapshot {
    /// Serialize for scrapers (`cutespmm metrics`, `serve --metrics-out`).
    /// `qos` is an empty array when the admission layer never engaged, so
    /// the key set is stable.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("responses", Json::num(self.responses as f64)),
            ("failures", Json::num(self.failures as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batched_requests", Json::num(self.batched_requests as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("avg_batch", Json::num(self.avg_batch)),
            ("request_latency", self.request_latency.to_json()),
            ("exec_latency", self.exec_latency.to_json()),
            ("served_gflop", Json::num(self.served_gflop)),
            (
                "engines",
                Json::arr(self.engines.iter().map(|l| {
                    Json::obj(vec![
                        ("engine", Json::str(l.engine)),
                        ("requests", Json::num(l.requests as f64)),
                        ("batches", Json::num(l.batches as f64)),
                        ("observed_us", Json::num(l.observed_us as f64)),
                        ("predicted_us", Json::num(l.predicted_us as f64)),
                        ("drift", Json::num(l.drift)),
                    ])
                })),
            ),
            (
                "artifacts",
                Json::obj(vec![
                    ("hits", Json::num(self.artifact_hits as f64)),
                    ("misses", Json::num(self.artifact_misses as f64)),
                    ("invalidated", Json::num(self.artifact_invalidated as f64)),
                ]),
            ),
            (
                "arena",
                Json::obj(vec![
                    ("hits", Json::num(self.arena_hits as f64)),
                    ("misses", Json::num(self.arena_misses as f64)),
                ]),
            ),
            (
                "reorder",
                Json::obj(vec![
                    ("matrices", Json::num(self.reorder.matrices as f64)),
                    ("alpha_before", Json::num(self.reorder.alpha_before)),
                    ("alpha_after", Json::num(self.reorder.alpha_after)),
                    ("beta_before", Json::num(self.reorder.beta_before)),
                    ("beta_after", Json::num(self.reorder.beta_after)),
                    ("prep_s", Json::num(self.reorder.seconds)),
                ]),
            ),
            (
                "qos",
                Json::arr(self.qos.iter().flatten().map(|l| {
                    Json::obj(vec![
                        ("lane", Json::str(l.lane)),
                        ("admitted", Json::num(l.admitted as f64)),
                        ("depth", Json::num(l.depth as f64)),
                        (
                            "shed",
                            Json::obj(
                                l.shed
                                    .iter()
                                    .map(|&(name, c)| (name, Json::num(c as f64)))
                                    .collect(),
                            ),
                        ),
                        ("queue_wait", l.queue_wait.to_json()),
                    ])
                })),
            ),
            ("qos_downstream_cost_s", Json::num(self.qos_downstream_cost_s)),
            (
                "trace",
                Json::obj(vec![
                    ("spans_recorded", Json::num(self.trace_spans_recorded as f64)),
                    ("spans_dropped", Json::num(self.trace_spans_dropped as f64)),
                ]),
            ),
            (
                "faults",
                Json::obj(vec![
                    ("engine_faults", Json::num(self.faults.engine_faults as f64)),
                    ("fallback_requests", Json::num(self.faults.fallback_requests as f64)),
                    ("quarantined", Json::num(self.faults.quarantined as f64)),
                    ("breaker_opens", Json::num(self.faults.opens as f64)),
                    ("breaker_closes", Json::num(self.faults.closes as f64)),
                    ("breaker_probes", Json::num(self.faults.probes as f64)),
                    ("injected", Json::num(self.faults.injected as f64)),
                ]),
            ),
            (
                "breakers",
                Json::arr(self.breakers.iter().map(|b| {
                    Json::obj(vec![
                        ("matrix", Json::str(b.matrix.as_str())),
                        ("state", Json::str(b.state)),
                    ])
                })),
            ),
        ])
    }

    /// The human-readable report line. Formats are stable against earlier
    /// releases except the latency header, which now includes p999.
    pub fn render(&self) -> String {
        let lat = &self.request_latency;
        let mut out = format!(
            "requests={} responses={} failures={} rejected={} batches={} \
             avg_batch={:.2} latency(mean/p50/p95/p99/p999/max µs)={:.0}/{}/{}/{}/{}/{} \
             served_gflop={:.3}",
            self.requests,
            self.responses,
            self.failures,
            self.rejected,
            self.batches,
            self.avg_batch,
            lat.mean_us,
            lat.p50_us,
            lat.p95_us,
            lat.p99_us,
            lat.p999_us,
            lat.max_us,
            self.served_gflop,
        );
        if !self.engines.is_empty() {
            out.push_str(" routing=[");
            for (i, l) in self.engines.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                if l.predicted_us > 0 {
                    out.push_str(&format!("{}:{}(drift={:.2}x)", l.engine, l.requests, l.drift));
                } else {
                    out.push_str(&format!("{}:{}", l.engine, l.requests));
                }
            }
            out.push(']');
        }
        if self.artifact_hits + self.artifact_misses + self.artifact_invalidated > 0 {
            out.push_str(&format!(
                " artifacts=[hits={} misses={} invalidated={}]",
                self.artifact_hits, self.artifact_misses, self.artifact_invalidated
            ));
        }
        if self.arena_hits + self.arena_misses > 0 {
            out.push_str(&format!(
                " arena=[hits={} misses={}]",
                self.arena_hits, self.arena_misses
            ));
        }
        if self.reorder.matrices > 0 {
            let rs = &self.reorder;
            let m = rs.matrices as f64;
            out.push_str(&format!(
                " reorder=[matrices={} alpha={:.4}->{:.4} beta={:.2}->{:.2} prep_s={:.3}]",
                rs.matrices,
                rs.alpha_before / m,
                rs.alpha_after / m,
                rs.beta_before / m,
                rs.beta_after / m,
                rs.seconds,
            ));
        }
        if let Some(qos) = &self.qos {
            out.push_str(" qos=[");
            for (i, l) in qos.iter().enumerate() {
                if i > 0 {
                    out.push_str(" | ");
                }
                out.push_str(&format!(
                    "{}: admitted={} depth={} wait_p99us={}",
                    l.lane, l.admitted, l.depth, l.queue_wait.p99_us,
                ));
                for &(name, c) in &l.shed {
                    if c > 0 {
                        out.push_str(&format!(" shed_{name}={c}"));
                    }
                }
            }
            out.push(']');
        }
        if self.trace_spans_recorded + self.trace_spans_dropped > 0 {
            out.push_str(&format!(
                " trace=[spans={} dropped={}]",
                self.trace_spans_recorded, self.trace_spans_dropped
            ));
        }
        if self.faults.any() {
            let fs = &self.faults;
            out.push_str(&format!(
                " faults=[engine={} fallback={} quarantined={} opens={} closes={} probes={} \
                 injected={}]",
                fs.engine_faults,
                fs.fallback_requests,
                fs.quarantined,
                fs.opens,
                fs.closes,
                fs.probes,
                fs.injected,
            ));
        }
        if !self.breakers.is_empty() {
            out.push_str(" breakers=[");
            for (i, b) in self.breakers.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&format!("{}:{}", b.matrix, b.state));
            }
            out.push(']');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1000, 5000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        let (p50, p95, p99, p999) = (
            h.percentile_us(50.0),
            h.percentile_us(95.0),
            h.percentile_us(99.0),
            h.percentile_us(99.9),
        );
        assert!(p50 <= p95 && p95 <= p99 && p99 <= p999 && p999 <= h.max_us());
        // interpolation keeps the estimate inside the true bucket instead
        // of returning its upper bound: the 5th of 10 samples is 160µs,
        // which lives in [128, 256) — the old code reported 256
        assert!((128..256).contains(&p50), "p50 {p50} escaped its bucket");
        // the tail lands in the 100_000µs sample's bucket [65536, 131072),
        // clamped to the observed max
        assert!((65536..=100_000).contains(&p999), "p999 {p999}");
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn percentile_interpolates_within_bucket() {
        // one sample: midpoint convention clamps to the observed max
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(160));
        assert_eq!(h.percentile_us(50.0), 160, "single sample reports itself, not 256");
        // two samples in the same [128, 256) bucket: quartile interpolation
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(130));
        h.record(Duration::from_micros(250));
        assert_eq!(h.percentile_us(50.0), 160, "rank 1 of 2 -> lo + 0.25 * width");
        assert_eq!(h.percentile_us(99.0), 224, "rank 2 of 2 -> lo + 0.75 * width");
    }

    #[test]
    fn histogram_summarize_carries_buckets_and_p999() {
        let h = LatencyHistogram::default();
        for us in [100u64, 100, 3000] {
            h.record(Duration::from_micros(us));
        }
        let s = h.summarize();
        assert_eq!(s.count, 3);
        assert_eq!(s.max_us, 3000);
        assert_eq!(s.p999_us, h.percentile_us(99.9));
        assert_eq!(s.buckets.len(), 2);
        assert_eq!(s.buckets[0], (64, 128, 2));
        assert_eq!(s.buckets[1], (2048, 4096, 1));
        let doc = crate::util::json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(doc.get("count").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("buckets").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn flops_accumulate_concurrently() {
        let m = Metrics::default();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        m.add_flops(1.5);
                    }
                });
            }
        });
        // 1.5 sums exactly in f64 at this magnitude, so the CAS loop must
        // lose no update
        assert_eq!(m.flops(), 8.0 * 1000.0 * 1.5);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_only_nonempty() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(100));
        let snap = h.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, 2);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.add_flops(1e9);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(r.contains("served_gflop=1.000"));
        assert!(r.contains("p999"), "tail percentile joined the latency header");
        assert!(!r.contains("routing="), "no lanes used -> no routing section");
    }

    /// Exercise every section, then check that report() is exactly the
    /// snapshot rendering and that each report field traces back to a
    /// snapshot field — the "no side-channel metrics" guarantee.
    #[test]
    fn report_is_rendered_from_snapshot() {
        let m = Metrics::default();
        m.requests.fetch_add(7, Ordering::Relaxed);
        m.responses.fetch_add(6, Ordering::Relaxed);
        m.failures.fetch_add(1, Ordering::Relaxed);
        m.rejected.fetch_add(2, Ordering::Relaxed);
        m.batches.fetch_add(3, Ordering::Relaxed);
        m.batched_requests.fetch_add(6, Ordering::Relaxed);
        m.add_flops(2.5e9);
        for us in [50u64, 400, 900, 12_000] {
            m.request_latency.record(Duration::from_micros(us));
            m.exec_latency.record(Duration::from_micros(us / 2));
        }
        m.record_route(Algo::Hrpb.index(), 6, Duration::from_micros(300), 150e-6);
        m.record_admitted(Priority::High);
        m.record_shed(Priority::Normal, RejectReason::Overload);
        m.record_queue_wait(Priority::High, Duration::from_micros(75));
        m.set_qos_depth(Priority::High, 2);
        m.add_qos_downstream(1e-3);
        m.sync_artifacts(crate::hrpb::StoreStats { hits: 2, misses: 1, invalidated: 0 });
        m.sync_arena(9, 3);
        let mut rs = ReorderSnapshot::default();
        rs.add(crate::reorder::Gains {
            alpha_before: 0.05,
            alpha_after: 0.3,
            beta_before: 1.0,
            beta_after: 1.0,
            seconds: 0.25,
        });
        m.sync_reorder(rs);

        let s = m.snapshot();
        let r = m.report();
        assert_eq!(r, s.render(), "report must be the snapshot rendering");
        // spot-check that rendered values come from snapshot fields
        assert!(r.contains(&format!("requests={}", s.requests)));
        assert!(r.contains(&format!("avg_batch={:.2}", s.avg_batch)));
        assert!(r.contains(&format!("served_gflop={:.3}", s.served_gflop)));
        assert!(r.contains(&format!(
            "={:.0}/{}/{}/{}/{}/{}",
            s.request_latency.mean_us,
            s.request_latency.p50_us,
            s.request_latency.p95_us,
            s.request_latency.p99_us,
            s.request_latency.p999_us,
            s.request_latency.max_us
        )));
        let l0 = &s.engines[0];
        assert!(r.contains(&format!("{}:{}(drift={:.2}x)", l0.engine, l0.requests, l0.drift)));
        let qos = s.qos.as_ref().expect("qos active");
        let high = qos.iter().find(|l| l.lane == "high").unwrap();
        assert!(r.contains(&format!(
            "high: admitted={} depth={} wait_p99us={}",
            high.admitted, high.depth, high.queue_wait.p99_us
        )));
        assert!(high.shed.iter().any(|&(_, c)| c == 0), "zero shed reasons stay visible");
        assert!((s.qos_downstream_cost_s - 1e-3).abs() < 1e-9);

        // the JSON export parses with the in-repo parser and mirrors the
        // snapshot (the scrape contract for `cutespmm metrics`)
        let doc = crate::util::json::parse(&s.to_json().to_string()).expect("snapshot JSON parses");
        assert_eq!(doc.get("requests").unwrap().as_usize(), Some(s.requests as usize));
        assert_eq!(
            doc.get("request_latency").unwrap().get("p999_us").unwrap().as_usize(),
            Some(s.request_latency.p999_us as usize)
        );
        assert_eq!(doc.get("engines").unwrap().as_arr().unwrap().len(), s.engines.len());
        assert_eq!(doc.get("qos").unwrap().as_arr().unwrap().len(), qos.len());
        assert_eq!(
            doc.get("qos").unwrap().as_arr().unwrap()[0]
                .get("shed")
                .unwrap()
                .get("overload")
                .unwrap()
                .as_usize(),
            Some(0),
            "high lane shed nothing but the key is present"
        );
        assert_eq!(doc.get("arena").unwrap().get("hits").unwrap().as_usize(), Some(9));
        assert_eq!(doc.get("reorder").unwrap().get("matrices").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn routing_lanes_accumulate_and_report() {
        let m = Metrics::default();
        m.record_route(Algo::Hrpb.index(), 4, Duration::from_micros(200), 100e-6);
        m.record_route(Algo::Hrpb.index(), 2, Duration::from_micros(200), 100e-6);
        m.record_route(Algo::Sputnik.index(), 1, Duration::from_micros(50), 0.0);
        assert_eq!(m.engine_requests(Algo::Hrpb), 6);
        assert_eq!(m.engine_requests(Algo::Sputnik), 1);
        assert_eq!(m.engine_requests(Algo::Csr), 0);

        let snap = m.engine_snapshot();
        assert_eq!(snap.len(), 2);
        let hrpb = snap.iter().find(|l| l.engine == "cutespmm").unwrap();
        assert_eq!(hrpb.batches, 2);
        assert_eq!(hrpb.observed_us, 400);
        assert_eq!(hrpb.predicted_us, 200);
        assert!((hrpb.drift - 2.0).abs() < 1e-9, "drift {}", hrpb.drift);
        let sput = snap.iter().find(|l| l.engine == "sputnik").unwrap();
        assert_eq!(sput.drift, 0.0, "no prediction -> no drift gauge");

        let r = m.report();
        assert!(r.contains("routing="), "{r}");
        assert!(r.contains("cutespmm:6(drift=2.00x)"), "{r}");
        assert!(r.contains("sputnik:1"), "{r}");
    }

    #[test]
    fn qos_lanes_record_and_report() {
        let m = Metrics::default();
        m.record_admitted(Priority::High);
        m.record_admitted(Priority::Normal);
        m.record_shed(Priority::Normal, RejectReason::Overload);
        m.record_shed(Priority::Normal, RejectReason::QueueFull);
        m.record_queue_wait(Priority::High, Duration::from_micros(100));
        m.set_qos_depth(Priority::High, 3);
        assert_eq!(m.shed_total(), 2);
        assert_eq!(m.qos[Priority::Normal.index()].shed_total(), 2);
        let r = m.report();
        assert!(r.contains("qos=["), "{r}");
        assert!(r.contains("high: admitted=1 depth=3"), "{r}");
        assert!(r.contains("shed_overload=1"), "{r}");
        assert!(r.contains("shed_full=1"), "{r}");
        assert!(!r.contains("shed_deadline"), "unused reasons stay silent: {r}");
    }

    #[test]
    fn qos_downstream_gauge_pairs_exactly() {
        let m = Metrics::default();
        assert_eq!(m.qos_downstream_cost_s(), 0.0);
        for cost in [1.5e-3, 2.25e-4, 0.0, -1.0] {
            m.add_qos_downstream(cost);
        }
        assert!(m.qos_downstream_cost_s() > 1.6e-3);
        for cost in [1.5e-3, 2.25e-4, 0.0, -1.0] {
            m.sub_qos_downstream(cost);
        }
        assert_eq!(m.qos_downstream_cost_us.load(Ordering::Relaxed), 0, "paired updates cancel");
    }

    #[test]
    fn qos_section_is_silent_without_activity() {
        let m = Metrics::default();
        m.requests.fetch_add(1, Ordering::Relaxed);
        assert!(!m.report().contains("qos=["));
    }

    #[test]
    fn artifact_counters_report_when_active_and_stay_silent_otherwise() {
        let m = Metrics::default();
        assert!(!m.report().contains("artifacts=["));
        m.sync_artifacts(crate::hrpb::StoreStats { hits: 3, misses: 1, invalidated: 2 });
        let r = m.report();
        assert!(r.contains("artifacts=[hits=3 misses=1 invalidated=2]"), "{r}");
        // absolute mirror: a later snapshot replaces, not accumulates
        m.sync_artifacts(crate::hrpb::StoreStats { hits: 4, misses: 1, invalidated: 2 });
        assert!(m.report().contains("hits=4"), "{}", m.report());
    }

    #[test]
    fn reorder_section_reports_means_and_stays_silent_otherwise() {
        let m = Metrics::default();
        assert!(!m.report().contains("reorder=["));
        let mut snap = ReorderSnapshot::default();
        snap.add(crate::reorder::Gains {
            alpha_before: 0.04,
            alpha_after: 0.20,
            beta_before: 1.0,
            beta_after: 1.0,
            seconds: 0.5,
        });
        snap.add(crate::reorder::Gains {
            alpha_before: 0.06,
            alpha_after: 0.40,
            beta_before: 1.0,
            beta_after: 1.0,
            seconds: 0.25,
        });
        m.sync_reorder(snap);
        let r = m.report();
        assert!(r.contains("reorder=[matrices=2 alpha=0.0500->0.3000"), "{r}");
        assert!(r.contains("prep_s=0.750"), "{r}");
        // absolute mirror: a later snapshot replaces, not accumulates
        m.sync_reorder(ReorderSnapshot::default());
        assert!(!m.report().contains("reorder=["));
    }

    #[test]
    fn arena_counters_report_when_active_and_stay_silent_otherwise() {
        let m = Metrics::default();
        assert!(!m.report().contains("arena=["));
        m.sync_arena(10, 2);
        assert!(m.report().contains("arena=[hits=10 misses=2]"), "{}", m.report());
        // absolute mirror: a later snapshot replaces, not accumulates
        m.sync_arena(11, 2);
        assert!(m.report().contains("arena=[hits=11 misses=2]"), "{}", m.report());
    }

    #[test]
    fn trace_counters_report_when_active_and_stay_silent_otherwise() {
        let m = Metrics::default();
        assert!(!m.report().contains("trace=["));
        m.sync_trace(120, 7);
        let r = m.report();
        assert!(r.contains("trace=[spans=120 dropped=7]"), "{r}");
        let s = m.snapshot();
        assert_eq!(r, s.render());
        let doc = crate::util::json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(doc.get("trace").unwrap().get("spans_recorded").unwrap().as_usize(), Some(120));
        assert_eq!(doc.get("trace").unwrap().get("spans_dropped").unwrap().as_usize(), Some(7));
        // absolute mirror: a later snapshot replaces, not accumulates
        m.sync_trace(240, 7);
        assert!(m.report().contains("trace=[spans=240 dropped=7]"), "{}", m.report());
    }

    #[test]
    fn fault_counters_report_when_active_and_stay_silent_otherwise() {
        let m = Metrics::default();
        let r = m.report();
        assert!(!r.contains("faults=["), "{r}");
        assert!(!r.contains("breakers=["), "{r}");
        assert!(!m.snapshot().faults.any());

        m.engine_faults.fetch_add(3, Ordering::Relaxed);
        m.fallback_requests.fetch_add(5, Ordering::Relaxed);
        m.quarantined_rejects.fetch_add(1, Ordering::Relaxed);
        m.sync_breakers(
            vec![
                BreakerEntry { matrix: "victim".into(), state: "open" },
                BreakerEntry { matrix: "cursed".into(), state: "quarantined" },
            ],
            super::super::breaker::BreakerCounters { opens: 2, closes: 1, probes: 4 },
        );
        m.sync_injected(9);

        let s = m.snapshot();
        assert_eq!(
            s.faults,
            FaultsSnapshot {
                engine_faults: 3,
                fallback_requests: 5,
                quarantined: 1,
                opens: 2,
                closes: 1,
                probes: 4,
                injected: 9,
            }
        );
        assert_eq!(s.breakers.len(), 2);
        let r = m.report();
        assert_eq!(r, s.render());
        assert!(
            r.contains(
                "faults=[engine=3 fallback=5 quarantined=1 opens=2 closes=1 probes=4 injected=9]"
            ),
            "{r}"
        );
        assert!(r.contains("breakers=[victim:open cursed:quarantined]"), "{r}");

        // the JSON export carries the same counters for scrapers
        let doc = crate::util::json::parse(&s.to_json().to_string()).unwrap();
        let faults = doc.get("faults").unwrap();
        assert_eq!(faults.get("engine_faults").unwrap().as_usize(), Some(3));
        assert_eq!(faults.get("fallback_requests").unwrap().as_usize(), Some(5));
        assert_eq!(faults.get("breaker_opens").unwrap().as_usize(), Some(2));
        assert_eq!(faults.get("injected").unwrap().as_usize(), Some(9));
        let breakers = doc.get("breakers").unwrap().as_arr().unwrap();
        assert_eq!(breakers.len(), 2);
        assert_eq!(breakers[0].get("matrix").unwrap().as_str(), Some("victim"));
        assert_eq!(breakers[0].get("state").unwrap().as_str(), Some("open"));

        // absolute mirrors: a later sync replaces, not accumulates
        m.sync_breakers(Vec::new(), super::super::breaker::BreakerCounters::default());
        m.sync_injected(0);
        let r = m.report();
        assert!(!r.contains("breakers=["), "{r}");
        assert!(r.contains("faults=[engine=3"), "contained-fault counters persist: {r}");
    }

    #[test]
    fn lane_names_cover_all_lanes() {
        for lane in 0..ENGINE_LANES {
            assert_ne!(lane_name(lane), "?", "lane {lane}");
        }
        assert_eq!(lane_name(PJRT_LANE), "pjrt");
        assert_eq!(lane_name(Algo::Hrpb.index()), "cutespmm");
    }
}
