//! Serving metrics: latency histograms, throughput counters, queue gauges.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log-2 bucketed latency histogram, microsecond resolution, thread-safe.
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^{i+1}) µs; 32 buckets = up to ~1h
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile from the log-2 buckets (upper bound of the
    /// bucket containing the p-quantile).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= want {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }

    /// (bucket upper bound µs, count) pairs for display.
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| (1u64 << (i + 1), b.load(Ordering::Relaxed)))
            .filter(|&(_, c)| c > 0)
            .collect()
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    /// End-to-end request latency (submit → response).
    pub request_latency: LatencyHistogram,
    /// Kernel execution latency per batch.
    pub exec_latency: LatencyHistogram,
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    /// Requests folded together across all batches (batching efficiency =
    /// batched / batches).
    pub batched_requests: AtomicU64,
    pub rejected: AtomicU64,
    pub queue_depth: AtomicUsize,
    /// FLOPs served (useful, 2·nnz·n per request).
    pub flops: Mutex<f64>,
}

impl Metrics {
    pub fn add_flops(&self, f: f64) {
        *self.flops.lock().unwrap() += f;
    }

    pub fn report(&self) -> String {
        let lat = &self.request_latency;
        format!(
            "requests={} responses={} failures={} rejected={} batches={} \
             avg_batch={:.2} latency(mean/p50/p95/p99/max µs)={:.0}/{}/{}/{}/{} \
             served_gflop={:.3}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed) as f64
                / self.batches.load(Ordering::Relaxed).max(1) as f64,
            lat.mean_us(),
            lat.percentile_us(50.0),
            lat.percentile_us(95.0),
            lat.percentile_us(99.0),
            lat.max_us(),
            *self.flops.lock().unwrap() / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1000, 5000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        assert!(h.percentile_us(95.0) <= h.percentile_us(99.9).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_only_nonempty() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(100));
        let snap = h.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, 2);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.add_flops(1e9);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(r.contains("served_gflop=1.000"));
    }
}
