//! Typed serving errors — every way a request can fail, as data.
//!
//! PR 9 replaces the serving path's `Result<Response, String>` with this
//! taxonomy so callers can *dispatch* on what went wrong instead of
//! pattern-matching prose: a shed request should be retried later with
//! backoff, an engine fault is transient and isolated to one matrix, a
//! quarantine is sticky until the operator intervenes, and a shutdown
//! means stop submitting. The `Display` impls keep the exact message
//! shapes the pre-typed path printed (`"rejected (...)"`,
//! `"B rows N != matrix cols M"`, `"coordinator stopped"`), so logs and
//! the CLI read the same while programs finally get structure.

use super::registry::MatrixId;
use crate::qos::Rejected;
use std::fmt;

/// Why a serving request failed. Carried on every reply channel in place
/// of the old stringly-typed error.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// The QoS admission layer shed the request — the typed
    /// [`Rejected`] says why (full / overload / deadline / shutdown) and
    /// what the estimated wait was.
    Shed(Rejected),
    /// The legacy bounded ingress channel is full (`try_submit`
    /// backpressure, no QoS layer configured).
    Busy,
    /// An engine/kernel panic was contained at the dispatch boundary.
    /// Only this request's batch failed; the serving loop survived.
    EngineFault { matrix: String, engine: &'static str, detail: String },
    /// The matrix faulted even on the scalar CSR fallback and its breaker
    /// is quarantined — requests are rejected until re-registration.
    Quarantined { matrix: String },
    /// The submitted id was never registered.
    UnknownMatrix(MatrixId),
    /// The dense operand's shape does not match the registered matrix.
    ShapeMismatch { got: usize, want: usize },
    /// The coordinator stopped (shutdown raced the submission, or the
    /// response channel was dropped).
    Shutdown,
    /// API misuse that used to kill the process (e.g. `submit_qos`
    /// without `Config::qos`).
    Misconfigured(&'static str),
}

impl ServeError {
    /// Stable snake_case discriminant name — what metrics and the CLI's
    /// per-kind error counts key on.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Shed(_) => "shed",
            ServeError::Busy => "busy",
            ServeError::EngineFault { .. } => "engine_fault",
            ServeError::Quarantined { .. } => "quarantined",
            ServeError::UnknownMatrix(_) => "unknown_matrix",
            ServeError::ShapeMismatch { .. } => "shape_mismatch",
            ServeError::Shutdown => "shutdown",
            ServeError::Misconfigured(_) => "misconfigured",
        }
    }

    /// Is this a contained engine fault? (The chaos suite's isolation
    /// assertions key on this.)
    pub fn is_fault(&self) -> bool {
        matches!(self, ServeError::EngineFault { .. })
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // keeps the "rejected (...)" prefix the qos tests and CLI
            // output have relied on since PR 5
            ServeError::Shed(r) => write!(f, "{r}"),
            ServeError::Busy => write!(f, "busy (ingress channel full)"),
            ServeError::EngineFault { matrix, engine, detail } => {
                write!(f, "engine fault ({engine}) serving {matrix}: {detail}")
            }
            ServeError::Quarantined { matrix } => {
                write!(f, "matrix {matrix} is quarantined (faulted on the fallback engine)")
            }
            ServeError::UnknownMatrix(id) => write!(f, "unknown matrix {id:?}"),
            ServeError::ShapeMismatch { got, want } => {
                write!(f, "B rows {got} != matrix cols {want}")
            }
            ServeError::Shutdown => write!(f, "coordinator stopped"),
            ServeError::Misconfigured(msg) => write!(f, "misconfigured: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::{Priority, RejectReason};
    use std::time::Duration;

    #[test]
    fn shed_display_keeps_the_rejected_prefix() {
        let e = ServeError::Shed(Rejected {
            reason: RejectReason::QueueFull,
            est_wait: Duration::from_millis(3),
            priority: Priority::Normal,
        });
        let s = e.to_string();
        assert!(s.starts_with("rejected"), "{s}");
        assert_eq!(e.kind(), "shed");
    }

    #[test]
    fn kinds_are_stable_and_distinct() {
        let errs = [
            ServeError::Busy,
            ServeError::EngineFault {
                matrix: "m".into(),
                engine: "cutespmm",
                detail: "boom".into(),
            },
            ServeError::Quarantined { matrix: "m".into() },
            ServeError::UnknownMatrix(MatrixId(7)),
            ServeError::ShapeMismatch { got: 3, want: 4 },
            ServeError::Shutdown,
            ServeError::Misconfigured("needs qos"),
        ];
        let kinds: Vec<&str> = errs.iter().map(|e| e.kind()).collect();
        let mut dedup = kinds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), kinds.len(), "kinds must be distinct: {kinds:?}");
        for e in &errs {
            assert!(!e.to_string().is_empty());
        }
        assert!(errs[1].is_fault());
        assert!(!errs[0].is_fault());
    }

    #[test]
    fn legacy_message_shapes_survive_the_typing() {
        assert_eq!(ServeError::Shutdown.to_string(), "coordinator stopped");
        assert_eq!(
            ServeError::ShapeMismatch { got: 8, want: 16 }.to_string(),
            "B rows 8 != matrix cols 16"
        );
        assert!(ServeError::UnknownMatrix(MatrixId(3)).to_string().contains("unknown matrix"));
    }
}
