//! Typed serving errors — every way a request can fail, as data.
//!
//! PR 9 replaces the serving path's `Result<Response, String>` with this
//! taxonomy so callers can *dispatch* on what went wrong instead of
//! pattern-matching prose: a shed request should be retried later with
//! backoff, an engine fault is transient and isolated to one matrix, a
//! quarantine is sticky until the operator intervenes, and a shutdown
//! means stop submitting. The `Display` impls keep the exact message
//! shapes the pre-typed path printed (`"rejected (...)"`,
//! `"B rows N != matrix cols M"`, `"coordinator stopped"`), so logs and
//! the CLI read the same while programs finally get structure.

use super::registry::MatrixId;
use crate::qos::{Priority, RejectReason, Rejected};
use crate::util::json::Json;
use std::fmt;
use std::time::Duration;

/// Why a serving request failed. Carried on every reply channel in place
/// of the old stringly-typed error.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// The QoS admission layer shed the request — the typed
    /// [`Rejected`] says why (full / overload / deadline / shutdown) and
    /// what the estimated wait was.
    Shed(Rejected),
    /// The legacy bounded ingress channel is full (`try_submit`
    /// backpressure, no QoS layer configured).
    Busy,
    /// An engine/kernel panic was contained at the dispatch boundary.
    /// Only this request's batch failed; the serving loop survived.
    EngineFault { matrix: String, engine: &'static str, detail: String },
    /// The matrix faulted even on the scalar CSR fallback and its breaker
    /// is quarantined — requests are rejected until re-registration.
    Quarantined { matrix: String },
    /// The submitted id was never registered.
    UnknownMatrix(MatrixId),
    /// The dense operand's shape does not match the registered matrix.
    ShapeMismatch { got: usize, want: usize },
    /// The coordinator stopped (shutdown raced the submission, or the
    /// response channel was dropped).
    Shutdown,
    /// API misuse that used to kill the process (e.g. `submit_qos`
    /// without `Config::qos`).
    Misconfigured(&'static str),
    /// A wire-protocol failure between the shard router and a shard: a
    /// hostile or corrupt frame, an undecodable payload, or a lost /
    /// timed-out connection. Transport-shaped — the shard router treats it
    /// as retryable on a replica (same idempotent request id), unlike the
    /// serving-semantics errors above.
    Protocol { detail: String },
}

impl ServeError {
    /// Stable snake_case discriminant name — what metrics and the CLI's
    /// per-kind error counts key on.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Shed(_) => "shed",
            ServeError::Busy => "busy",
            ServeError::EngineFault { .. } => "engine_fault",
            ServeError::Quarantined { .. } => "quarantined",
            ServeError::UnknownMatrix(_) => "unknown_matrix",
            ServeError::ShapeMismatch { .. } => "shape_mismatch",
            ServeError::Shutdown => "shutdown",
            ServeError::Misconfigured(_) => "misconfigured",
            ServeError::Protocol { .. } => "protocol",
        }
    }

    /// Stable numeric wire code — what the PR 10 binary protocol carries
    /// in the response status field. Codes are append-only and PINNED
    /// FOREVER (see `wire_codes_are_pinned_forever` below): a renumbering
    /// would silently re-type every error a newer peer sends an older one.
    /// 0 is reserved for "ok" on the wire and never a ServeError.
    pub fn code(&self) -> u16 {
        match self {
            ServeError::Shed(_) => 1,
            ServeError::Busy => 2,
            ServeError::EngineFault { .. } => 3,
            ServeError::Quarantined { .. } => 4,
            ServeError::UnknownMatrix(_) => 5,
            ServeError::ShapeMismatch { .. } => 6,
            ServeError::Shutdown => 7,
            ServeError::Misconfigured(_) => 8,
            ServeError::Protocol { .. } => 9,
        }
    }

    /// Is this a contained engine fault? (The chaos suite's isolation
    /// assertions key on this.)
    pub fn is_fault(&self) -> bool {
        matches!(self, ServeError::EngineFault { .. })
    }

    /// Is this a transport-shaped failure (lost/stalled connection, bad
    /// frame) whose outcome on the shard is unknown? The shard router
    /// retries these on a replica with the same idempotent request id;
    /// serving-semantics errors are returned to the caller as-is.
    pub fn is_transport(&self) -> bool {
        matches!(self, ServeError::Protocol { .. })
    }

    /// Serialize for the wire: the stable code plus enough structure to
    /// reconstruct the variant on the peer ([`ServeError::from_json`]).
    /// `kind` and `message` ride along for logs and for peers that only
    /// want to print.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::num(self.code() as f64)),
            ("kind", Json::str(self.kind())),
            ("message", Json::str(&self.to_string())),
        ];
        match self {
            ServeError::Shed(r) => {
                fields.push(("reason", Json::str(r.reason.name())));
                fields.push(("est_wait_us", Json::num(r.est_wait.as_micros() as f64)));
                fields.push(("priority", Json::str(r.priority.name())));
            }
            ServeError::EngineFault { matrix, engine, detail } => {
                fields.push(("matrix", Json::str(matrix)));
                fields.push(("engine", Json::str(engine)));
                fields.push(("detail", Json::str(detail)));
            }
            ServeError::Quarantined { matrix } => fields.push(("matrix", Json::str(matrix))),
            ServeError::UnknownMatrix(id) => fields.push(("matrix_id", Json::num(id.0 as f64))),
            ServeError::ShapeMismatch { got, want } => {
                fields.push(("got", Json::num(*got as f64)));
                fields.push(("want", Json::num(*want as f64)));
            }
            ServeError::Protocol { detail } => fields.push(("detail", Json::str(detail))),
            ServeError::Busy | ServeError::Shutdown | ServeError::Misconfigured(_) => {}
        }
        Json::obj(fields)
    }

    /// Reconstruct from [`ServeError::to_json`] output. Dispatches on the
    /// stable code, never on message prose. `None` for unknown codes or a
    /// malformed document (a future-peer error decodes as `None`, and the
    /// wire layer degrades it to a typed `Protocol` error — never a panic).
    pub fn from_json(j: &Json) -> Option<ServeError> {
        let code = j.get("code")?.as_f64()? as u16;
        let s = |key: &str| j.get(key).and_then(|v| v.as_str()).map(str::to_string);
        Some(match code {
            1 => {
                let reason_name = s("reason")?;
                let reason = RejectReason::all().into_iter().find(|r| r.name() == reason_name)?;
                let priority = Priority::parse(&s("priority")?)?;
                let est_wait =
                    Duration::from_micros(j.get("est_wait_us")?.as_f64().filter(|v| *v >= 0.0)?
                        as u64);
                ServeError::Shed(Rejected { reason, est_wait, priority })
            }
            2 => ServeError::Busy,
            3 => ServeError::EngineFault {
                matrix: s("matrix")?,
                engine: intern_engine(&s("engine")?),
                detail: s("detail")?,
            },
            4 => ServeError::Quarantined { matrix: s("matrix")? },
            5 => ServeError::UnknownMatrix(MatrixId(j.get("matrix_id")?.as_f64()? as u64)),
            6 => ServeError::ShapeMismatch {
                got: j.get("got")?.as_usize()?,
                want: j.get("want")?.as_usize()?,
            },
            7 => ServeError::Shutdown,
            // the &'static str payload cannot cross a process boundary;
            // the message field preserves the prose for logs
            8 => ServeError::Misconfigured("misconfigured on the remote peer (see message)"),
            9 => ServeError::Protocol { detail: s("detail")? },
            _ => return None,
        })
    }
}

/// Map a wire engine name back to the `&'static str` the enum carries.
/// Unknown names (a newer peer's engine) degrade to a stable marker
/// instead of failing the decode.
fn intern_engine(name: &str) -> &'static str {
    const KNOWN: [&str; 8] =
        ["cutespmm-native", "cutespmm", "pjrt", "csr", "csr-fallback", "sputnik", "tcgnn", "dense"];
    KNOWN.iter().find(|k| **k == name).copied().unwrap_or("remote-engine")
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // keeps the "rejected (...)" prefix the qos tests and CLI
            // output have relied on since PR 5
            ServeError::Shed(r) => write!(f, "{r}"),
            ServeError::Busy => write!(f, "busy (ingress channel full)"),
            ServeError::EngineFault { matrix, engine, detail } => {
                write!(f, "engine fault ({engine}) serving {matrix}: {detail}")
            }
            ServeError::Quarantined { matrix } => {
                write!(f, "matrix {matrix} is quarantined (faulted on the fallback engine)")
            }
            ServeError::UnknownMatrix(id) => write!(f, "unknown matrix {id:?}"),
            ServeError::ShapeMismatch { got, want } => {
                write!(f, "B rows {got} != matrix cols {want}")
            }
            ServeError::Shutdown => write!(f, "coordinator stopped"),
            ServeError::Misconfigured(msg) => write!(f, "misconfigured: {msg}"),
            ServeError::Protocol { detail } => write!(f, "protocol error: {detail}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::{Priority, RejectReason};
    use std::time::Duration;

    #[test]
    fn shed_display_keeps_the_rejected_prefix() {
        let e = ServeError::Shed(Rejected {
            reason: RejectReason::QueueFull,
            est_wait: Duration::from_millis(3),
            priority: Priority::Normal,
        });
        let s = e.to_string();
        assert!(s.starts_with("rejected"), "{s}");
        assert_eq!(e.kind(), "shed");
    }

    #[test]
    fn kinds_are_stable_and_distinct() {
        let errs = [
            ServeError::Busy,
            ServeError::EngineFault {
                matrix: "m".into(),
                engine: "cutespmm",
                detail: "boom".into(),
            },
            ServeError::Quarantined { matrix: "m".into() },
            ServeError::UnknownMatrix(MatrixId(7)),
            ServeError::ShapeMismatch { got: 3, want: 4 },
            ServeError::Shutdown,
            ServeError::Misconfigured("needs qos"),
            ServeError::Protocol { detail: "bad frame".into() },
        ];
        let kinds: Vec<&str> = errs.iter().map(|e| e.kind()).collect();
        let mut dedup = kinds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), kinds.len(), "kinds must be distinct: {kinds:?}");
        for e in &errs {
            assert!(!e.to_string().is_empty());
        }
        assert!(errs[1].is_fault());
        assert!(!errs[0].is_fault());
    }

    /// Every variant's wire code, pinned forever. A new variant APPENDS a
    /// code; changing any tabulated pair here is a wire-compatibility
    /// break with every peer ever shipped, so this test must never be
    /// "fixed" to accommodate a renumbering.
    #[test]
    fn wire_codes_are_pinned_forever() {
        let pinned: [(ServeError, u16, &str); 9] = [
            (
                ServeError::Shed(Rejected {
                    reason: RejectReason::QueueFull,
                    est_wait: Duration::ZERO,
                    priority: Priority::Normal,
                }),
                1,
                "shed",
            ),
            (ServeError::Busy, 2, "busy"),
            (
                ServeError::EngineFault {
                    matrix: "m".into(),
                    engine: "cutespmm",
                    detail: "d".into(),
                },
                3,
                "engine_fault",
            ),
            (ServeError::Quarantined { matrix: "m".into() }, 4, "quarantined"),
            (ServeError::UnknownMatrix(MatrixId(1)), 5, "unknown_matrix"),
            (ServeError::ShapeMismatch { got: 1, want: 2 }, 6, "shape_mismatch"),
            (ServeError::Shutdown, 7, "shutdown"),
            (ServeError::Misconfigured("x"), 8, "misconfigured"),
            (ServeError::Protocol { detail: "d".into() }, 9, "protocol"),
        ];
        for (err, code, kind) in &pinned {
            assert_eq!(err.code(), *code, "code for {kind} is pinned");
            assert_eq!(err.kind(), *kind);
        }
        // codes are dense, distinct, and 0 stays reserved for "ok"
        let codes: Vec<u16> = pinned.iter().map(|(e, _, _)| e.code()).collect();
        assert_eq!(codes, (1..=9).collect::<Vec<u16>>());
    }

    #[test]
    fn json_round_trip_preserves_code_kind_and_structure() {
        let errs = [
            ServeError::Shed(Rejected {
                reason: RejectReason::DeadlineUnmeetable,
                est_wait: Duration::from_micros(1234),
                priority: Priority::High,
            }),
            ServeError::Busy,
            ServeError::EngineFault {
                matrix: "victim".into(),
                engine: "cutespmm",
                detail: "injected kernel fault".into(),
            },
            ServeError::Quarantined { matrix: "victim".into() },
            ServeError::UnknownMatrix(MatrixId(42)),
            ServeError::ShapeMismatch { got: 8, want: 16 },
            ServeError::Shutdown,
            ServeError::Protocol { detail: "bad checksum".into() },
        ];
        for e in &errs {
            // through text, as the wire does it
            let text = e.to_json().to_string();
            let back = ServeError::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap_or_else(|| panic!("{} must decode", e.kind()));
            assert_eq!(back.code(), e.code());
            assert_eq!(back.kind(), e.kind());
            // non-Misconfigured variants reconstruct their Display too
            assert_eq!(back.to_string(), e.to_string());
        }
        // Misconfigured round-trips code/kind; the &'static str payload is
        // summarized (it cannot cross a process boundary)
        let m = ServeError::Misconfigured("needs qos");
        let back =
            ServeError::from_json(&crate::util::json::parse(&m.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.code(), 8);
        assert_eq!(back.kind(), "misconfigured");
    }

    #[test]
    fn from_json_rejects_unknown_codes_and_garbage_without_panicking() {
        use crate::util::json::{parse, Json};
        assert!(ServeError::from_json(&Json::obj(vec![("code", Json::num(999.0))])).is_none());
        assert!(ServeError::from_json(&Json::obj(vec![])).is_none());
        assert!(ServeError::from_json(&parse("{\"code\": 3}").unwrap()).is_none(), "missing fields");
        assert!(ServeError::from_json(&Json::str("nope")).is_none());
        // an unknown engine name degrades to a marker, not a failure
        let j = parse(
            "{\"code\": 3, \"matrix\": \"m\", \"engine\": \"quantum\", \"detail\": \"d\"}",
        )
        .unwrap();
        match ServeError::from_json(&j) {
            Some(ServeError::EngineFault { engine, .. }) => assert_eq!(engine, "remote-engine"),
            other => panic!("expected an EngineFault, got {other:?}"),
        }
    }

    #[test]
    fn transport_errors_are_the_only_retryable_class() {
        assert!(ServeError::Protocol { detail: "x".into() }.is_transport());
        assert!(!ServeError::Shutdown.is_transport());
        assert!(!ServeError::Busy.is_transport());
        assert!(!ServeError::EngineFault {
            matrix: "m".into(),
            engine: "csr",
            detail: "d".into()
        }
        .is_transport());
    }

    #[test]
    fn legacy_message_shapes_survive_the_typing() {
        assert_eq!(ServeError::Shutdown.to_string(), "coordinator stopped");
        assert_eq!(
            ServeError::ShapeMismatch { got: 8, want: 16 }.to_string(),
            "B rows 8 != matrix cols 16"
        );
        assert!(ServeError::UnknownMatrix(MatrixId(3)).to_string().contains("unknown matrix"));
    }
}
