//! cutespmm CLI — leader entrypoint.
//!
//! ```text
//! cutespmm gen --name <recipe|family spec> --out m.mtx
//! cutespmm preprocess --mtx m.mtx            # HRPB stats + synergy
//! cutespmm prep <dir> [--matrix cora|--mtx m.mtx] [--scale S]
//!               [--threads N] [--force]     # persist HRPB artifacts for
//!                                           # warm-start registration
//! cutespmm spmm --mtx m.mtx --n 128 [--algo cutespmm] [--pjrt]
//! cutespmm synergy --mtx m.mtx [--n 128]
//! cutespmm plan --matrix cora [--n 128] [--machine a100] [--calibrate [rows]]
//!               [--profile calib.json] [--json] [--artifact-dir DIR]
//!                                           # ranked engine table + rationale
//! cutespmm serve --matrix cora --requests 200 --n 32
//!               [--engine native|pjrt|auto] [--calibrate] [--pjrt]
//!               [--artifact-dir DIR]        # warm-start registration
//!               [--qos] [--qos-capacity N] [--qos-watermark-ms MS]
//!               [--qos-deadline-ms MS]      # bounded admission + shedding
//!               [--trace-out t.trace.json]  # Chrome/Perfetto span export
//!               [--trace-sample RATE] [--trace-ring N] [--no-trace-kernel]
//!               [--metrics-out m.json]      # structured MetricsSnapshot
//!               [--metrics-every N]         # rewrite every N responses
//!               [--fault-plan SPEC]         # arm deterministic fault
//!               [--chaos-seed N]            # injection for this run
//!                                           # (spec: point[@target]
//!                                           #  [:rate=R|:nth=N][;...])
//! cutespmm metrics [--from m.json] [--json]  # validate + summarize a
//!                                            # snapshot dump
//! cutespmm metrics --diff a.json b.json [--json]
//!                                           # per-counter/lane/engine delta
//!                                           # report between two snapshots
//! cutespmm experiment <fig2|fig7|fig9|fig10|table1|table2|table3|table4|
//!                      preproc|prep|ablation-tiles|ablation-balance|auto|
//!                      qos|exec|reorder|trace|geometry|chaos|load|all>
//!                      [--quick] [--out-dir DIR]
//!                      [--fault-plan SPEC] [--chaos-seed N]
//!                                           # exec: pool + column-slab
//!                                           # runtime A/B, emits
//!                                           # results/BENCH_PR4.json
//!                                           # reorder: similarity-clustered
//!                                           # row-packing A/B, emits
//!                                           # results/BENCH_PR5.json
//!                                           # trace: observability overhead
//!                                           # off/sampled/full, emits
//!                                           # results/BENCH_PR6.json
//!                                           # geometry: planner-picked brick
//!                                           # shape vs fixed 16x4, emits
//!                                           # results/BENCH_PR8.json
//!                                           # chaos: fault injection —
//!                                           # containment, breakers,
//!                                           # quarantine, recovery, emits
//!                                           # results/BENCH_PR9.json
//!                                           # load: closed-loop clients vs
//!                                           # the shard router — RPS, tail
//!                                           # latency, shard-kill failover,
//!                                           # emits results/BENCH_PR10.json
//!                                           # prep/qos/auto/exec/reorder/
//!                                           # trace/geometry/chaos/load also
//!                                           # append a schema-v1 entry to
//!                                           # results/history/
//! cutespmm experiment diff [--against ID|FILE] [--slip PCT] [--json]
//!                          [--inject-slip [PCT]]
//!                                           # compare the latest history
//!                                           # entry against the accepted
//!                                           # (or previous) baseline; exits
//!                                           # nonzero on a regression
//! cutespmm experiment accept [run-id]       # pin the accepted baseline
//! cutespmm selfcheck                          # engines vs oracle + PJRT
//! ```
//!
//! Arguments are parsed by hand: the offline image has no clap (DESIGN.md §9).

use cutespmm::bench::{experiments, harness, render};
use cutespmm::coordinator::{BatchPolicy, Config, Coordinator, EnginePolicy};
use cutespmm::formats::{mtx, Coo, Dense};
use cutespmm::gen::named;
use cutespmm::gpumodel::{algos as gpu_algos, Machine, MatrixProfile};
use cutespmm::planner::{Calibration, Planner, PlannerConfig};
use cutespmm::qos::{Priority, QosConfig};
use cutespmm::runtime;
use cutespmm::spmm::Algo;
use cutespmm::util::json::Json;
use cutespmm::util::rng::Rng;
use cutespmm::util::timer::{measure, time_once};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

/// Minimal flag parser: `--key value` pairs plus bare flags.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let value = argv.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if value.is_some() {
                    i += 1;
                }
                flags.push((key.to_string(), value));
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn load_matrix(args: &Args) -> Result<(String, Coo), String> {
    if let Some(path) = args.get("mtx") {
        let coo = mtx::read_mtx(&PathBuf::from(path)).map_err(|e| e.to_string())?;
        return Ok((path.to_string(), coo));
    }
    if let Some(name) = args.get("matrix") {
        let scale = args.usize_or("scale", 1);
        let spec = named::scaled(name, scale)
            .ok_or_else(|| format!("unknown named matrix '{name}' (see gen --list)"))?;
        return Ok((spec.name.clone(), spec.generate()));
    }
    Err("need --mtx <file> or --matrix <name>".into())
}

fn cmd_gen(args: &Args) -> Result<(), String> {
    if args.has("list") {
        println!("named recipes (Tables 3/4):");
        for m in named::all() {
            println!("  {:<16} nodes={:<9} edges={}", m.name, m.nodes, m.edges);
        }
        return Ok(());
    }
    let (name, coo) = load_matrix(args)?;
    let out = args.get("out").ok_or("need --out <file.mtx>")?;
    mtx::write_mtx(&PathBuf::from(out), &coo, Some(&format!("cutespmm gen {name}")))
        .map_err(|e| e.to_string())?;
    println!("wrote {}: {}x{} nnz={}", out, coo.rows, coo.cols, coo.nnz());
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<(), String> {
    let (name, coo) = load_matrix(args)?;
    let (hrpb, t) = time_once(|| cutespmm::hrpb::build_from_coo(&coo));
    let stats = cutespmm::hrpb::stats::compute(&hrpb);
    println!("matrix {name}: {}x{} nnz={}", coo.rows, coo.cols, coo.nnz());
    println!(
        "HRPB: panels={} blocks={} bricks={} alpha={:.4} beta={:.2} packed={}B meta={}B ({:.2}x CSR)",
        stats.num_panels,
        stats.num_blocks,
        stats.num_bricks,
        stats.alpha,
        stats.beta,
        stats.packed_bytes,
        stats.meta_bytes,
        (stats.packed_bytes + stats.meta_bytes) as f64 / stats.csr_bytes(coo.rows) as f64,
    );
    println!("preprocessing: {:.3} ms", t * 1e3);
    Ok(())
}

/// `cutespmm prep <dir>`: build HRPB artifacts ahead of serving so node
/// (re)starts warm-start registration instead of re-paying §6.3's
/// preprocessing per matrix. Without `--matrix`/`--mtx` it preps the small
/// named GNN corpus.
fn cmd_prep(args: &Args) -> Result<(), String> {
    use cutespmm::hrpb::ArtifactStore;
    use cutespmm::planner::fingerprint;

    let dir = args
        .positional
        .get(1)
        .cloned()
        .ok_or("need a directory: cutespmm prep <dir> [--matrix name] [--threads N] [--force]")?;
    let store = ArtifactStore::open(&dir)?;
    let default_threads =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let threads = args.usize_or("threads", default_threads).max(1);

    let matrices: Vec<(String, Coo)> = if args.get("matrix").is_some() || args.get("mtx").is_some()
    {
        vec![load_matrix(args)?]
    } else {
        let scale = args.usize_or("scale", 1);
        ["cora", "citeseer", "pubmed", "artist", "PROTEINS_full"]
            .iter()
            .filter_map(|n| named::scaled(n, scale))
            .map(|spec| (spec.name.clone(), spec.generate()))
            .collect()
    };

    let mut rows = Vec::new();
    for (name, coo) in &matrices {
        let fp = fingerprint(coo);
        let digest = cutespmm::hrpb::serialize::content_digest(coo);
        if store.contains(fp) && !args.has("force") {
            let (loaded, t) =
                time_once(|| store.load_matching(fp, coo.rows, coo.cols, coo.nnz(), digest));
            if loaded.is_some() {
                rows.push(vec![
                    name.clone(),
                    coo.nnz().to_string(),
                    format!("{fp:016x}"),
                    "warm".into(),
                    format!("{:.2}", t * 1e3),
                ]);
                continue;
            }
            // fell through: the existing artifact was invalid — rebuild below
        }
        let (hrpb, t_build) =
            time_once(|| cutespmm::hrpb::build_with_parallel(
                &cutespmm::formats::Csr::from_coo(coo),
                cutespmm::params::TM,
                cutespmm::params::TK,
                threads,
            ));
        let stats = cutespmm::hrpb::stats::compute(&hrpb);
        store.save(fp, &hrpb, &stats, digest, None)?;
        rows.push(vec![
            name.clone(),
            coo.nnz().to_string(),
            format!("{fp:016x}"),
            "built".into(),
            format!("{:.2}", t_build * 1e3),
        ]);
    }
    println!(
        "{}",
        render::table(&["matrix", "nnz", "fingerprint", "source", "time(ms)"], &rows)
    );
    let st = store.stats();
    println!(
        "artifact dir {dir}: {} artifact(s) on disk, this run hits={} misses={} invalidated={} \
         (threads={threads})",
        store.list().len(),
        st.hits,
        st.misses,
        st.invalidated,
    );
    Ok(())
}

fn cmd_synergy(args: &Args) -> Result<(), String> {
    let (name, coo) = load_matrix(args)?;
    let n = args.usize_or("n", 128);
    let p = MatrixProfile::compute(&coo);
    let oi = cutespmm::synergy::model(&p.hrpb, n);
    println!("matrix {name}: alpha={:.4} synergy={}", p.hrpb.alpha, p.synergy().name());
    println!(
        "OI_shmem={:.1} (closed form 512a={:.1}), beta={:.2}, fill={:.1}x",
        oi.oi_shmem,
        cutespmm::synergy::oi_shmem_closed_form(p.hrpb.alpha),
        p.hrpb.beta,
        p.hrpb.fill_ratio
    );
    for m in [Machine::a100(), Machine::rtx4090()] {
        let cute = gpu_algos::predict(Algo::Hrpb, &p, n, &m);
        let (ba, best) = gpu_algos::predict_best_sc(&p, n, &m);
        let tc = gpu_algos::predict(Algo::TcGnn, &p, n, &m);
        println!(
            "[{}] modeled GFLOPs @N={n}: cuTeSpMM={:.0} ({}), best-SC={:.0} ({}), tcgnn={:.0} -> speedup {:.2}x",
            m.name,
            cute.gflops,
            cute.bound.name(),
            best.gflops,
            ba.name(),
            tc.gflops,
            cute.gflops / best.gflops
        );
    }
    Ok(())
}

/// Build a planner from the shared CLI flags (`--machine`, `--n`,
/// `--profile`, `--calibrate [rows]`).
fn planner_from_args(args: &Args, n: usize) -> Result<Planner, String> {
    let machine = match args.get("machine") {
        Some(m) => Machine::by_name(m).ok_or_else(|| format!("unknown machine '{m}'"))?,
        None => Machine::a100(),
    };
    let planner = Planner::with_config(PlannerConfig { machine, width: n, ..Default::default() });
    if let Some(path) = args.get("profile") {
        match Calibration::load(Path::new(path)) {
            Ok(c) => {
                println!("loaded calibration profile {path} (machine {})", c.machine);
                planner.set_calibration(c);
            }
            // a missing/bad profile is only acceptable when --calibrate is
            // about to (re)write it; otherwise the user would silently run
            // uncalibrated
            Err(e) if args.has("calibrate") => {
                eprintln!("calibration profile {path} not loaded ({e}); writing it after calibration");
            }
            Err(e) => return Err(format!("failed to load calibration profile {path}: {e}")),
        }
    }
    if args.has("calibrate") {
        let rows = args.usize_or("calibrate", 8192).max(256);
        eprintln!("calibrating candidate engines on this host (rows={rows}, width={n}) ...");
        let c = planner.calibrate(rows);
        for algo in cutespmm::planner::CANDIDATES {
            eprintln!("  {:<10} model x {:.3e}", algo.name(), c.scale_for(algo));
        }
        if let Some(path) = args.get("profile") {
            c.save(Path::new(path))?;
            println!("saved calibration profile to {path}");
        }
    }
    Ok(planner)
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    let (name, coo) = load_matrix(args)?;
    let n = args.usize_or("n", 128);
    let planner = planner_from_args(args, n)?;
    // --artifact-dir: plan off the persisted HRPB when one exists (warm, no
    // build), and persist HRPB + plan when it does not (cold)
    let (plan, t_plan) = match args.get("artifact-dir") {
        Some(dir) => {
            let store = cutespmm::hrpb::ArtifactStore::open(dir)?;
            let fp = cutespmm::planner::fingerprint(&coo);
            let digest = cutespmm::hrpb::serialize::content_digest(&coo);
            match store.load_matching(fp, coo.rows, coo.cols, coo.nnz(), digest) {
                Some(artifact) => {
                    // reuse the stored plan when it was evaluated at this
                    // width (same rule as the registry); otherwise re-plan
                    // off the loaded HRPB — still no build
                    let (plan, t) = time_once(|| match artifact.plan {
                        Some(p) if p.width == n => {
                            let p = Arc::new(p);
                            planner.seed_plan(p.clone());
                            p
                        }
                        _ => planner.plan_with_hrpb(&coo, &artifact.hrpb),
                    });
                    println!("artifact: warm hit ({})", store.path_for(fp).display());
                    (plan, t)
                }
                None => {
                    let ((hrpb, plan), t) = time_once(|| {
                        use cutespmm::params::{TK, TM};
                        let threads = std::thread::available_parallelism()
                            .map(|p| p.get())
                            .unwrap_or(1);
                        let csr = cutespmm::formats::Csr::from_coo(&coo);
                        // the same planner-gated reorder decision the serving
                        // registry makes — a plan-persisted artifact must
                        // never pin the arrival order for later warm starts
                        let t_reorder = std::time::Instant::now();
                        let proposal = cutespmm::reorder::propose(&csr, TM, TK);
                        // ... and the same pre-build geometry pricing: the
                        // catalog is priced under the row order about to be
                        // built, and the winner is built exactly once
                        let (hrpb, gains) = if planner.gate_reorder(&proposal) {
                            let gains =
                                proposal.gains(t_reorder.elapsed().as_secs_f64());
                            let priced = cutespmm::reorder::price_catalog(
                                &csr,
                                Some(&proposal.perm),
                                TM,
                                TK,
                            );
                            let geo = planner.choose_geometry(&priced);
                            let hrpb = cutespmm::reorder::build_reordered_geo(
                                &csr,
                                proposal.perm,
                                geo,
                                TM,
                                TK,
                                threads,
                            );
                            (hrpb, Some(gains))
                        } else {
                            let priced =
                                cutespmm::reorder::price_catalog(&csr, None, TM, TK);
                            let geo = planner.choose_geometry(&priced);
                            (
                                cutespmm::hrpb::build_with_geometry_parallel(
                                    &csr, geo, TM, TK, threads,
                                ),
                                None,
                            )
                        };
                        let mut profile =
                            cutespmm::gpumodel::MatrixProfile::with_hrpb(&coo, &hrpb);
                        profile.reorder = gains;
                        let plan = planner.plan_assembled(fp, &profile);
                        (hrpb, plan)
                    });
                    let stats = cutespmm::hrpb::stats::compute(&hrpb);
                    store.save(fp, &hrpb, &stats, digest, Some(plan.as_ref()))?;
                    println!("artifact: cold build, persisted to {}", store.path_for(fp).display());
                    (plan, t)
                }
            }
        }
        None => time_once(|| planner.plan(&coo)),
    };

    if args.has("json") {
        // machine-readable: the ranked-engine table for scripts
        let doc = Json::obj(vec![
            ("matrix", Json::str(name.clone())),
            ("rows", Json::num(coo.rows as f64)),
            ("cols", Json::num(coo.cols as f64)),
            ("nnz", Json::num(coo.nnz() as f64)),
            ("machine", Json::str(planner.machine().name)),
            ("calibrated", Json::Bool(planner.calibration().calibrated)),
            ("plan_ms", Json::num(t_plan * 1e3)),
            ("plan", plan.to_json()),
        ]);
        println!("{}", doc.to_string());
        return Ok(());
    }

    println!(
        "matrix {name}: {}x{} nnz={} — planned in {:.2} ms",
        coo.rows,
        coo.cols,
        coo.nnz(),
        t_plan * 1e3
    );
    println!(
        "alpha={:.4} synergy={} OI_shmem={:.1} (512a) geometry={} machine={} width={n}",
        plan.alpha,
        plan.synergy.name(),
        512.0 * plan.alpha,
        plan.geometry,
        planner.machine().name,
    );
    let calibrated = planner.calibration().calibrated;
    let mut rows = Vec::new();
    for (rank, c) in plan.ranked.iter().enumerate() {
        rows.push(vec![
            format!("{}", rank + 1),
            c.algo.name().to_string(),
            format!("{:.1}", c.predicted_s * 1e6),
            format!("{:.1}", c.modeled_s * 1e6),
            c.bound.name().to_string(),
            if c.algo == plan.engine { "<- chosen".to_string() } else { String::new() },
        ]);
    }
    let pred_header = if calibrated { "predicted(us)" } else { "predicted(us,model)" };
    println!(
        "{}",
        render::table(&["rank", "engine", pred_header, "modeled(us)", "bound", ""], &rows)
    );
    println!("chosen: {} — {}", plan.engine.name(), plan.rationale);
    if let Some(g) = plan.reorder {
        println!(
            "reorder: active — alpha {:.4}->{:.4} beta {:.2}->{:.2} (one-time {:.1} ms)",
            g.alpha_before,
            g.alpha_after,
            g.beta_before,
            g.beta_after,
            g.seconds * 1e3
        );
    }
    let cache = planner.cache().stats();
    println!("plan cache: {} hits / {} misses / {} entries", cache.hits, cache.misses, cache.entries);
    Ok(())
}

fn cmd_spmm(args: &Args) -> Result<(), String> {
    let (name, coo) = load_matrix(args)?;
    let n = args.usize_or("n", 128);
    let algo = args
        .get("algo")
        .map(|a| Algo::parse(a).ok_or_else(|| format!("unknown algo '{a}'")))
        .transpose()?
        .unwrap_or(Algo::Hrpb);
    let mut rng = Rng::new(args.usize_or("seed", 1) as u64);
    let b = Dense::random(coo.cols, n, &mut rng);

    if args.has("pjrt") {
        let svc = runtime::PjrtService::start(runtime::default_artifacts_dir())?;
        let hrpb = std::sync::Arc::new(cutespmm::hrpb::build_from_coo(&coo));
        let handle = svc.handle();
        let (c, t) = time_once(|| handle.spmm(hrpb.clone(), b.clone()));
        let c = c?;
        let gf = 2.0 * coo.nnz() as f64 * n as f64 / t / 1e9;
        println!("{name} via PJRT ({}): {:.3} ms, {:.2} GFLOP/s, C={}x{}",
                 handle.platform()?, t * 1e3, gf, c.rows, c.cols);
        return Ok(());
    }

    let (engine, t_prep) = time_once(|| algo.prepare(&coo));
    let meas = measure(1, args.usize_or("samples", 5), || {
        let _ = engine.spmm(&b);
    });
    println!(
        "{name} via {}: prep {:.3} ms, spmm {:.3} ms (median of {}), {:.2} GFLOP/s useful",
        engine.name(),
        t_prep * 1e3,
        meas.median_s * 1e3,
        meas.samples,
        engine.flops(n) / meas.median_s / 1e9
    );
    Ok(())
}

/// Parse `--fault-plan <spec>` (+ optional `--chaos-seed <n>`) into a
/// validated [`cutespmm::fault::FaultPlan`] without arming anything.
/// Parsing is all-or-nothing: a bad spec (or a seed without a plan)
/// returns `Err` — and hence a nonzero exit — before any injection point
/// is armed, so a typo can never leave a partial plan installed.
fn fault_plan_from_args(args: &Args) -> Result<Option<cutespmm::fault::FaultPlan>, String> {
    let Some(spec) = args.get("fault-plan") else {
        if args.get("chaos-seed").is_some() {
            return Err("--chaos-seed requires --fault-plan <spec>".into());
        }
        return Ok(None);
    };
    let seed = match args.get("chaos-seed") {
        Some(v) => v.parse::<u64>().map_err(|_| format!("--chaos-seed '{v}' is not a u64"))?,
        None => 0xC4A0,
    };
    let plan = cutespmm::fault::FaultPlan::parse(spec, seed)
        .map_err(|e| format!("--fault-plan '{spec}': {e}"))?;
    Ok(Some(plan))
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let (name, coo) = load_matrix(args)?;
    let n = args.usize_or("n", 32);
    let requests = args.usize_or("requests", 200);
    let workers = args.usize_or("workers", 4);
    // --fault-plan: validated up front so a bad spec exits before any
    // service (coordinator, PJRT) is started; armed just before the
    // coordinator so registration-time artifact IO is covered too
    let fault_plan = fault_plan_from_args(args)?;

    // --engine {native,pjrt,auto}; the legacy --pjrt flag implies pjrt
    let engine = match args.get("engine") {
        Some(e) => EnginePolicy::parse(e)
            .ok_or_else(|| format!("unknown engine policy '{e}' (native|pjrt|auto)"))?,
        None if args.has("pjrt") => EnginePolicy::PreferPjrt,
        None => EnginePolicy::Native,
    };
    let pjrt_svc = if engine == EnginePolicy::PreferPjrt {
        Some(runtime::PjrtService::start(runtime::default_artifacts_dir())?)
    } else {
        None
    };
    let planner = if engine == EnginePolicy::Auto {
        Some(Arc::new(planner_from_args(args, n.max(1))?))
    } else {
        None
    };
    // --qos puts the bounded admission layer in front of the batcher
    let qos = if args.has("qos") {
        Some(QosConfig {
            queue_capacity: args.usize_or("qos-capacity", 256),
            watermark_s: args.usize_or("qos-watermark-ms", 50) as f64 / 1e3,
            default_deadline: args
                .get("qos-deadline-ms")
                .and_then(|v| v.parse::<u64>().ok())
                .map(Duration::from_millis),
        })
    } else {
        None
    };
    // --artifact-dir: registration warm-starts from persisted artifacts
    let artifact_dir = args.get("artifact-dir").map(PathBuf::from);
    // --trace-out enables request + kernel tracing for this run; the trace
    // session is process-global, so hold the guard across start → drain
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let trace_cfg = cutespmm::trace::TraceConfig {
        enabled: trace_out.is_some(),
        sample_rate: args.get("trace-sample").and_then(|v| v.parse().ok()).unwrap_or(1.0),
        kernel: !args.has("no-trace-kernel"),
        ring_capacity: args.usize_or("trace-ring", 1 << 16),
    };
    let _trace_session = trace_out.as_ref().map(|_| cutespmm::trace::session_guard());
    // --metrics-out dumps the structured MetricsSnapshot as JSON; with
    // --metrics-every N it is rewritten every N responses (a poor man's
    // scrape endpoint), and always once more at the end of the run
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let metrics_every = args.usize_or("metrics-every", 0);
    if let Some(plan) = &fault_plan {
        cutespmm::fault::install(plan);
        println!("fault injection armed: {} arm(s), seed {}", plan.injections.len(), plan.seed);
    }
    let coord = Coordinator::start_with_planner(
        Config {
            workers,
            engine,
            batch: BatchPolicy::default(),
            qos,
            artifact_dir,
            trace: trace_cfg,
            ..Default::default()
        },
        pjrt_svc.as_ref().map(|s| s.handle()),
        planner,
    );
    if let Some(q) = &qos {
        println!(
            "qos: capacity={} watermark={:.1}ms deadline={}",
            q.queue_capacity,
            q.watermark_s * 1e3,
            q.default_deadline
                .map(|d| format!("{}ms", d.as_millis()))
                .unwrap_or_else(|| "none".into()),
        );
    }
    let id = coord.register(&name, &coo);
    let entry = coord.registry().get(id).unwrap();
    println!(
        "registered {name}: {}x{} nnz={} synergy={} engine-policy={} (preprocess {:.1} ms)",
        entry.rows,
        entry.cols,
        entry.nnz,
        entry.synergy.name(),
        engine.name(),
        entry.preprocess_time.as_secs_f64() * 1e3
    );
    if let Some(plan) = &entry.plan {
        println!(
            "plan: engine={} predicted={:.1} us/batch@{} — {}",
            plan.engine.name(),
            plan.predicted_s * 1e6,
            plan.width,
            plan.rationale
        );
    }

    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(7);
    let mut rxs = Vec::with_capacity(requests);
    let mut shed = 0usize;
    for i in 0..requests {
        let b = Dense::random(coo.cols, n, &mut rng);
        if qos.is_some() {
            // every 4th request rides the high-priority lane so the
            // per-lane metrics exercise both lanes; sheds are counted from
            // the typed rejection at submission time
            let priority = if i % 4 == 0 { Priority::High } else { Priority::Normal };
            match coord.submit_qos(id, b, priority, None) {
                Ok(rx) => rxs.push(rx),
                Err((_rejected, _b)) => shed += 1,
            }
        } else {
            rxs.push(coord.submit(id, b));
        }
    }
    let dump_metrics = |path: &PathBuf| -> Result<(), String> {
        std::fs::write(path, coord.metrics().snapshot().to_json().to_string())
            .map_err(|e| format!("cannot write {}: {e}", path.display()))
    };
    let mut ok = 0usize;
    // per-kind tally of typed errors: a faulting run still answers every
    // request, so the breakdown (engine_fault=.. quarantined=..) is the
    // operator-visible evidence of containment
    let mut error_kinds: Vec<(&'static str, usize)> = Vec::new();
    for rx in rxs {
        match rx.recv().map_err(|e| e.to_string())? {
            Ok(_) => ok += 1,
            Err(e) => match error_kinds.iter_mut().find(|(k, _)| *k == e.kind()) {
                Some((_, count)) => *count += 1,
                None => error_kinds.push((e.kind(), 1)),
            },
        }
        if metrics_every > 0 && ok > 0 && ok % metrics_every == 0 {
            if let Some(path) = &metrics_out {
                dump_metrics(path)?;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let shed_note = if shed > 0 { format!(", {shed} shed at admission") } else { String::new() };
    println!(
        "served {ok}/{requests} requests in {:.3} s ({:.1} req/s){shed_note}",
        wall,
        ok as f64 / wall
    );
    if !error_kinds.is_empty() {
        let parts: Vec<String> =
            error_kinds.iter().map(|(kind, count)| format!("{kind}={count}")).collect();
        println!("errors: {}", parts.join(" "));
    }
    if fault_plan.is_some() {
        println!("injected faults fired: {}", cutespmm::fault::fired_total());
    }
    println!("{}", coord.metrics().report());
    if let Some(path) = &metrics_out {
        dump_metrics(path)?;
        println!("metrics snapshot -> {}", path.display());
    }
    // shutdown ordering: coordinator first (workers hold PJRT handles),
    // then the PJRT service
    coord.shutdown();
    if let Some(path) = &trace_out {
        let tr = cutespmm::trace::drain();
        cutespmm::trace::disable();
        tr.write_chrome(path).map_err(|e| format!("cannot write {}: {e}", path.display()))?;
        println!(
            "trace -> {} ({} spans, {} dropped; open at https://ui.perfetto.dev)",
            path.display(),
            tr.spans.len(),
            tr.dropped
        );
    }
    if let Some(svc) = pjrt_svc {
        svc.shutdown();
    }
    if fault_plan.is_some() {
        cutespmm::fault::disable();
    }
    Ok(())
}

/// `cutespmm metrics`: validate and summarize a [`MetricsSnapshot`] JSON
/// dump produced by `serve --metrics-out`. `--json` re-emits the validated
/// document (the CI smoke uses the nonzero exit on parse failure as its
/// snapshot-validity assertion).
fn cmd_metrics(args: &Args) -> Result<(), String> {
    if let Some(a_path) = args.get("diff") {
        let b_path = args
            .positional
            .get(1)
            .ok_or("usage: cutespmm metrics --diff <baseline.json> <current.json>")?;
        return metrics_diff(Path::new(a_path), Path::new(b_path), args.has("json"));
    }
    let path = args
        .get("from")
        .map(PathBuf::from)
        .unwrap_or_else(|| experiments::results_dir().join("metrics.json"));
    let text = std::fs::read_to_string(&path).map_err(|e| {
        format!(
            "cannot read {} ({e}); produce one with `cutespmm serve --metrics-out <path>`",
            path.display()
        )
    })?;
    let doc = cutespmm::util::json::parse(&text)
        .map_err(|e| format!("{} is not a valid metrics snapshot: {e}", path.display()))?;
    if args.has("json") {
        println!("{}", doc.to_string());
        return Ok(());
    }
    let num = |key: &str| doc.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!("metrics snapshot {}:", path.display());
    println!(
        "  requests={} responses={} failures={} rejected={} batches={}",
        num("requests"),
        num("responses"),
        num("failures"),
        num("rejected"),
        num("batches"),
    );
    if let Some(lat) = doc.get("request_latency") {
        let l = |k: &str| lat.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "  request latency(us): mean={:.0} p50={:.0} p95={:.0} p99={:.0} p999={:.0} max={:.0}",
            l("mean_us"),
            l("p50_us"),
            l("p95_us"),
            l("p99_us"),
            l("p999_us"),
            l("max_us"),
        );
    }
    println!("  served_gflop={:.3}", num("served_gflop"));
    if let Some(engines) = doc.get("engines").and_then(|v| v.as_arr()) {
        for e in engines {
            println!(
                "  engine {}: requests={} batches={} observed_us={}",
                e.get("engine").and_then(|v| v.as_str()).unwrap_or("?"),
                e.get("requests").and_then(|v| v.as_f64()).unwrap_or(0.0),
                e.get("batches").and_then(|v| v.as_f64()).unwrap_or(0.0),
                e.get("observed_us").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
        }
    }
    if let Some(trace) = doc.get("trace") {
        let t = |k: &str| trace.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let (recorded, dropped) = (t("spans_recorded"), t("spans_dropped"));
        if recorded + dropped > 0.0 {
            println!("  trace: spans_recorded={recorded} spans_dropped={dropped}");
        }
    }
    Ok(())
}

/// `cutespmm metrics --diff a.json b.json`: per-counter, per-engine-lane and
/// per-QoS-lane delta report between two snapshot dumps, using the same
/// percent-change math as the experiment regression gate.
fn metrics_diff(a_path: &Path, b_path: &Path, json: bool) -> Result<(), String> {
    use harness::diff::pct_change;

    let load = |p: &Path| -> Result<Json, String> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("cannot read {}: {e}", p.display()))?;
        cutespmm::util::json::parse(&text)
            .map_err(|e| format!("{} is not a valid metrics snapshot: {e}", p.display()))
    };
    let a = load(a_path)?;
    let b = load(b_path)?;
    let at = |d: &Json, path: &[&str]| -> f64 {
        let mut cur = d;
        for key in path {
            match cur.get(key) {
                Some(next) => cur = next,
                None => return 0.0,
            }
        }
        cur.as_f64().unwrap_or(0.0)
    };

    // (section, metric, json path) — the scalar counters and percentiles a
    // lane-level comparison cares about
    let mut entries: Vec<(String, String, Vec<String>)> = Vec::new();
    let own = |path: &[&str]| path.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    for key in ["requests", "responses", "failures", "rejected", "batches", "served_gflop"] {
        entries.push(("counters".to_string(), key.to_string(), own(&[key])));
    }
    for hist in ["request_latency", "exec_latency"] {
        for q in ["p50_us", "p99_us", "p999_us", "mean_us"] {
            entries.push((hist.to_string(), q.to_string(), own(&[hist, q])));
        }
    }
    for key in ["spans_recorded", "spans_dropped"] {
        entries.push(("trace".to_string(), key.to_string(), own(&["trace", key])));
    }
    // engine lanes present in either snapshot, matched by name
    let lane_rows = |doc: &Json, section: &str| -> Vec<String> {
        doc.get(section)
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|l| {
                        l.get(if section == "engines" { "engine" } else { "lane" })
                            .and_then(|v| v.as_str())
                            .map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let lane_value = |doc: &Json, section: &str, lane: &str, path: &[&str]| -> f64 {
        let key = if section == "engines" { "engine" } else { "lane" };
        doc.get(section)
            .and_then(|v| v.as_arr())
            .and_then(|arr| {
                arr.iter().find(|l| l.get(key).and_then(|v| v.as_str()) == Some(lane))
            })
            .map(|l| at(l, path))
            .unwrap_or(0.0)
    };
    let mut lanes: Vec<(String, String)> = Vec::new();
    for section in ["engines", "qos"] {
        let mut names = lane_rows(&a, section);
        for n in lane_rows(&b, section) {
            if !names.contains(&n) {
                names.push(n);
            }
        }
        for name in names {
            lanes.push((section.to_string(), name));
        }
    }

    let mut rows = Vec::new();
    let mut json_entries = Vec::new();
    let mut push = |section: &str, metric: &str, base: f64, cur: f64| {
        if base == 0.0 && cur == 0.0 {
            return; // idle sections stay out of the report
        }
        let change = pct_change(base, cur);
        rows.push(vec![
            section.to_string(),
            metric.to_string(),
            format!("{base}"),
            format!("{cur}"),
            change.map(|p| format!("{p:+.1}%")).unwrap_or_else(|| "-".to_string()),
        ]);
        json_entries.push(Json::obj(vec![
            ("section", Json::str(section)),
            ("metric", Json::str(metric)),
            ("baseline", Json::num(base)),
            ("current", Json::num(cur)),
            (
                "change_pct",
                change.map(Json::num).unwrap_or(Json::Null),
            ),
        ]));
    };
    for (section, metric, path) in &entries {
        let path: Vec<&str> = path.iter().map(String::as_str).collect();
        push(section, metric, at(&a, &path), at(&b, &path));
    }
    for (section, lane) in &lanes {
        let metrics: &[(&str, &[&str])] = if section == "engines" {
            &[
                ("requests", &["requests"]),
                ("observed_us", &["observed_us"]),
                ("drift", &["drift"]),
            ]
        } else {
            &[
                ("admitted", &["admitted"]),
                ("p99_wait_us", &["queue_wait", "p99_us"]),
            ]
        };
        for (metric, path) in metrics {
            push(
                &format!("{section}/{lane}"),
                metric,
                lane_value(&a, section, lane, path),
                lane_value(&b, section, lane, path),
            );
        }
    }

    if json {
        let doc = Json::obj(vec![
            ("kind", Json::str("cutespmm_metrics_diff")),
            ("baseline", Json::str(a_path.display().to_string())),
            ("current", Json::str(b_path.display().to_string())),
            ("entries", Json::Arr(json_entries)),
        ]);
        println!("{}", doc.to_string());
        return Ok(());
    }
    println!("metrics diff: {} (baseline) vs {} (current)", a_path.display(), b_path.display());
    if rows.is_empty() {
        println!("both snapshots are empty — nothing to compare");
        return Ok(());
    }
    println!("{}", render::table(&["section", "metric", "baseline", "current", "change"], &rows));
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<(), String> {
    let mut rng = Rng::new(99);
    let coo = Coo::random(200, 300, 0.03, &mut rng);
    let b = Dense::random(300, 32, &mut rng);
    let want = coo.to_dense().matmul(&b);
    let mut failures = 0;
    for algo in Algo::all() {
        let engine = algo.prepare(&coo);
        let err = engine.spmm(&b).rel_fro_error(&want);
        let ok = err < 1e-4;
        println!("  engine {:<10} rel_err={err:.2e} {}", algo.name(), if ok { "OK" } else { "FAIL" });
        failures += usize::from(!ok);
    }
    if args.has("pjrt") || runtime::artifacts_available() {
        match runtime::PjrtService::start(runtime::default_artifacts_dir()) {
            Ok(svc) => {
                let hrpb = std::sync::Arc::new(cutespmm::hrpb::build_from_coo(&coo));
                let err = svc
                    .handle()
                    .spmm(hrpb, b.clone())?
                    .rel_fro_error(&want);
                let ok = err < 1e-3;
                println!("  engine {:<10} rel_err={err:.2e} {}", "pjrt", if ok { "OK" } else { "FAIL" });
                failures += usize::from(!ok);
            }
            Err(e) => println!("  engine pjrt skipped: {e}"),
        }
    } else {
        println!("  engine pjrt skipped: artifacts not built (run `make artifacts`)");
    }
    if failures > 0 {
        return Err(format!("{failures} engine(s) failed selfcheck"));
    }
    println!("selfcheck OK");
    Ok(())
}

/// The nine suites the perf observatory tracks: they run through
/// [`harness::run_suite`] (same reports, same `BENCH_*.json` artifacts)
/// and additionally append to `results/history/`.
const HARNESS_SUITES: [&str; 9] =
    ["prep", "auto", "qos", "exec", "reorder", "trace", "geometry", "chaos", "load"];

fn cmd_experiment(args: &Args) -> Result<(), String> {
    // --out-dir relocates every CSV/JSON artifact, including the history
    // dir, before anything runs
    if let Some(dir) = args.get("out-dir") {
        experiments::set_results_dir(PathBuf::from(dir));
    }
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    if which == "diff" {
        return cmd_experiment_diff(args);
    }
    if which == "accept" {
        return cmd_experiment_accept(args);
    }
    // --fault-plan arms injection across the whole run. The chaos suite
    // installs its own per-mode plans (and disables on exit) regardless, so
    // a CLI-armed plan is for stressing the *other* drivers under faults.
    let fault_plan = fault_plan_from_args(args)?;
    if let Some(plan) = &fault_plan {
        cutespmm::fault::install(plan);
        eprintln!("fault injection armed: {} arm(s), seed {}", plan.injections.len(), plan.seed);
    }
    let quick = args.has("quick");
    let needs_corpus =
        matches!(which, "fig2" | "fig7" | "fig9" | "fig10" | "table2" | "auto" | "all");
    let records = if needs_corpus {
        eprintln!(
            "generating + profiling the {} corpus ...",
            if quick { "quick (1/10)" } else { "full ~1100-matrix" }
        );
        experiments::corpus_records(quick)
    } else {
        Vec::new()
    };
    let run = |name: &str, report: String| {
        println!("{report}");
        eprintln!("[{name}] csv -> {}", experiments::results_dir().display());
    };
    let run_suite = |name: &str| -> Result<harness::SuiteRun, String> {
        let recs = if name == "auto" { Some(records.as_slice()) } else { None };
        let sr = harness::run_suite(name, quick, recs)?;
        run(name, sr.report.clone());
        Ok(sr)
    };
    let mut suite_runs: Vec<harness::SuiteRun> = Vec::new();
    match which {
        "fig2" => run("fig2", experiments::fig2(&records)),
        "fig7" => run("fig7", experiments::fig7(&records)),
        "fig9" => run("fig9", experiments::fig9(&records)),
        "fig10" => run("fig10", experiments::fig10(&records)),
        "table1" => run("table1", experiments::table1()),
        "table2" => run("table2", experiments::table2(&records)),
        "table3" => run("table3", experiments::table34(3)),
        "table4" => run("table4", experiments::table34(4)),
        "preproc" => run("preproc", experiments::preprocessing()),
        "ablation-tiles" => run("ablation-tiles", experiments::ablation_tiles()),
        "ablation-balance" => run("ablation-balance", experiments::ablation_loadbalance()),
        name if HARNESS_SUITES.contains(&name) => suite_runs.push(run_suite(name)?),
        "all" => {
            run("table1", experiments::table1());
            run("table2", experiments::table2(&records));
            run("fig2", experiments::fig2(&records));
            run("fig7", experiments::fig7(&records));
            run("fig9", experiments::fig9(&records));
            run("fig10", experiments::fig10(&records));
            run("table3", experiments::table34(3));
            run("table4", experiments::table34(4));
            run("preproc", experiments::preprocessing());
            run("ablation-tiles", experiments::ablation_tiles());
            // the observatory suites run last, collected into ONE history
            // entry for the whole invocation
            for name in HARNESS_SUITES {
                suite_runs.push(run_suite(name)?);
            }
        }
        other => return Err(format!("unknown experiment '{other}'")),
    }
    if !suite_runs.is_empty() {
        let flags: Vec<String> = std::env::args().skip(1).collect();
        let file = harness::collect(quick, &flags, suite_runs);
        match harness::history::append(&file) {
            Ok(path) => eprintln!("[{which}] history -> {} (run {})", path.display(), file.run_id),
            Err(e) => eprintln!("warning: could not record history entry: {e}"),
        }
    }
    if fault_plan.is_some() {
        cutespmm::fault::disable();
    }
    Ok(())
}

/// `cutespmm experiment diff`: compare the latest history entry against
/// the accepted (or previous, or `--against`) baseline per headline.
/// Exits nonzero when any headline slipped beyond its threshold — the CI
/// regression gate. `--inject-slip [PCT]` self-tests the gate by diffing
/// a synthetically degraded copy of the latest run against itself.
fn cmd_experiment_diff(args: &Args) -> Result<(), String> {
    use harness::{diff, history};

    let slip_override = args.get("slip").and_then(|v| v.parse::<f64>().ok());
    let current_id = history::latest().ok_or(
        "no history entries yet; run `cutespmm experiment all --quick` (or any of \
         prep/auto/qos/exec/reorder/trace/geometry/chaos/load) first",
    )?;
    let current = history::load(&current_id)?;
    let (base, cur) = if args.has("inject-slip") {
        let pct = args.get("inject-slip").and_then(|v| v.parse::<f64>().ok()).unwrap_or(15.0);
        eprintln!(
            "self-test: diffing run {current_id} against a copy degraded by {pct}% — \
             the gate must go red"
        );
        let slipped = diff::inject_slip(&current, pct);
        (current, slipped)
    } else if let Some(id) = args.get("against") {
        let as_path = Path::new(id);
        let base = if as_path.is_file() {
            // a file path baselines against an arbitrary results document,
            // including pre-harness BENCH_PR*.json records
            history::load_path(as_path)?
        } else {
            history::load(id)?
        };
        (base, current)
    } else if let Some(id) = history::baseline_for(&current_id) {
        let kind = if history::accepted_id().as_deref() == Some(id.as_str()) {
            "accepted"
        } else {
            "previous entry"
        };
        eprintln!("baseline: {id} ({kind})");
        (history::load(&id)?, current)
    } else {
        println!(
            "no baseline to compare against (first recorded run is {current_id}); \
             nothing to gate — pass"
        );
        return Ok(());
    };
    let report = diff::diff(&base, &cur, slip_override);
    if args.has("json") {
        println!("{}", report.to_json().to_string());
    } else {
        print!("{}", report.render());
    }
    if report.regressed() {
        return Err(format!(
            "regression gate: run {} slipped beyond threshold vs baseline {}",
            report.current_id, report.baseline_id
        ));
    }
    Ok(())
}

/// `cutespmm experiment accept [run-id]`: pin the accepted baseline the
/// regression gate diffs against (defaults to the latest entry).
fn cmd_experiment_accept(args: &Args) -> Result<(), String> {
    use harness::history;

    let id = match args.positional.get(2) {
        Some(id) => id.clone(),
        None => history::latest().ok_or("no history entries to accept")?,
    };
    let path = history::accept(&id)?;
    println!("accepted baseline {id} -> {}", path.display());
    Ok(())
}

fn usage() -> &'static str {
    "usage: cutespmm <gen|preprocess|prep|spmm|synergy|plan|serve|metrics|experiment|selfcheck> \
     [flags]\n\
     perf observatory: `experiment all --quick` records a run under results/history/, \
     `experiment diff [--against ID|FILE] [--slip PCT] [--inject-slip [PCT]] [--json]` \
     gates on headline regressions, `experiment accept [run-id]` pins the baseline, \
     `metrics --diff a.json b.json` compares two snapshot dumps\n\
     fault tolerance: `experiment chaos --quick` runs the deterministic fault-injection \
     harness (containment, breakers, quarantine, recovery), and `serve`/`experiment` \
     accept `--fault-plan \"point[@target][:rate=R|:nth=N][;...]\" [--chaos-seed N]`\n\
     network serving: `experiment load --quick` drives concurrent closed-loop clients \
     over the sharded wire protocol (sustained RPS, p50/p99/p99.9, bounded queues, \
     shard-kill failover with zero lost/duplicated, net_stall/net_drop faults)\n\
     see the module docs at the top of rust/src/main.rs for flag details"
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "gen" => cmd_gen(&args),
        "preprocess" => cmd_preprocess(&args),
        "prep" => cmd_prep(&args),
        "spmm" => cmd_spmm(&args),
        "synergy" => cmd_synergy(&args),
        "plan" => cmd_plan(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        "experiment" => cmd_experiment(&args),
        "selfcheck" => cmd_selfcheck(&args),
        "" | "help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(argv: &[&str]) -> Args {
        Args::parse(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn fault_plan_flag_parses_spec_and_seed() {
        let a = args(&["serve", "--fault-plan", "kernel_panic@cora:nth=1", "--chaos-seed", "7"]);
        let plan = fault_plan_from_args(&a).unwrap().expect("plan must parse");
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.injections.len(), 1);
        assert_eq!(plan.injections[0].target.as_deref(), Some("cora"));
    }

    #[test]
    fn fault_plan_seed_defaults_when_not_given() {
        let a = args(&["serve", "--fault-plan", "slow_exec:rate=0.5"]);
        let plan = fault_plan_from_args(&a).unwrap().expect("plan must parse");
        assert_eq!(plan.seed, 0xC4A0);
    }

    #[test]
    fn absent_fault_plan_is_none() {
        assert!(fault_plan_from_args(&args(&["serve"])).unwrap().is_none());
    }

    #[test]
    fn bad_fault_plan_is_rejected_whole_with_nothing_armed() {
        // one good arm + one bad arm: the whole spec must be rejected and
        // nothing armed — no partial plans
        let a = args(&["serve", "--fault-plan", "kernel_panic;bogus_point:rate=1"]);
        let err = fault_plan_from_args(&a).unwrap_err();
        assert!(err.contains("bogus_point"), "{err}");
        assert!(!cutespmm::fault::enabled(), "a rejected spec must not arm anything");
    }

    #[test]
    fn chaos_seed_without_a_plan_is_an_error() {
        let err = fault_plan_from_args(&args(&["serve", "--chaos-seed", "9"])).unwrap_err();
        assert!(err.contains("--fault-plan"), "{err}");
    }

    #[test]
    fn non_numeric_chaos_seed_is_rejected() {
        let a = args(&["serve", "--fault-plan", "kernel_panic", "--chaos-seed", "seven"]);
        let err = fault_plan_from_args(&a).unwrap_err();
        assert!(err.contains("u64"), "{err}");
    }
}
