//! Ablations: the §4 tile-size sweep (TM/TK/TN via the OI model) and the §5
//! load-balancing scheme comparison (measured on the native engine).

use cutespmm::bench::experiments;

fn main() {
    println!("{}", experiments::ablation_tiles());
    println!("{}", experiments::ablation_loadbalance());
}
