//! Regenerates the paper's Fig. 10 speedup heatmaps (cuTeSpMM and TC-GNN
//! over Best-SC, binned by row count × synergy class).
//!
//! `CUTESPMM_FULL=1 cargo bench --bench bench_fig10` for the full corpus.

use cutespmm::bench::experiments;

fn main() {
    let quick = std::env::var_os("CUTESPMM_FULL").is_none();
    let records = experiments::corpus_records(quick);
    println!("{}", experiments::fig10(&records));
}
