//! Auto-policy experiment: adaptive engine selection (planner decision rule)
//! vs fixed policies vs the per-matrix oracle, over the synthetic corpus.
//!
//! `cargo bench --bench bench_auto` (quick 1/10 corpus by default;
//! set `CUTESPMM_FULL=1` for the full ~1100-matrix run).

use cutespmm::bench::experiments;

fn main() {
    let quick = std::env::var_os("CUTESPMM_FULL").is_none();
    let records = experiments::corpus_records(quick);
    println!("{}", experiments::auto_policy(&records));
}
