//! Measured CPU benchmark of the executable engines (this testbed's real
//! numbers, feeding EXPERIMENTS.md §Perf): the native HRPB hot path vs the
//! scalar baselines and the TC-GNN emulation, across structure regimes and
//! dense widths.

use cutespmm::formats::Dense;
use cutespmm::gen::{Family, MatrixSpec};
use cutespmm::spmm::Algo;
use cutespmm::util::timer::measure;

fn main() {
    let cases = vec![
        (
            "fem-like (high synergy)",
            MatrixSpec {
                name: "fem".into(),
                rows: 60_000,
                family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
                seed: 1,
            },
        ),
        (
            "mesh2d (medium synergy)",
            MatrixSpec { name: "mesh".into(), rows: 60_000, family: Family::Mesh { dims: 2 }, seed: 2 },
        ),
        (
            "rmat (low synergy)",
            MatrixSpec {
                name: "rmat".into(),
                rows: 60_000,
                family: Family::Rmat { edge_factor: 8, skew: 0.57 },
                seed: 3,
            },
        ),
        (
            "chem blockdiag (high synergy)",
            MatrixSpec {
                name: "chem".into(),
                rows: 60_000,
                family: Family::BlockDiag { unit: 24, unit_density: 0.25 },
                seed: 4,
            },
        ),
    ];
    let algos = [Algo::Hrpb, Algo::Csr, Algo::Sputnik, Algo::GeSpmm, Algo::Coo, Algo::TcGnn];

    println!("== native engine benchmark (measured on this CPU) ==");
    println!(
        "{:<30} {:>8} {:>6} {:>10} {:>12} {:>10}",
        "matrix", "algo", "N", "time(ms)", "GFLOP/s", "vs cute"
    );
    for (label, spec) in cases {
        let coo = spec.generate();
        for n in [32usize, 128] {
            let b = Dense::from_vec(coo.cols, n, vec![0.5; coo.cols * n]);
            let mut out = Dense::zeros(coo.rows, n);
            let mut cute_time = None;
            for algo in algos {
                let engine = algo.prepare(&coo);
                // spmm_into with a reused buffer: kernel time, not allocator
                let m = measure(1, 3, || {
                    engine.spmm_into(&b, &mut out);
                });
                if algo == Algo::Hrpb {
                    cute_time = Some(m.median_s);
                }
                let rel = cute_time.map(|c| m.median_s / c).unwrap_or(1.0);
                println!(
                    "{:<30} {:>8} {:>6} {:>10.3} {:>12.2} {:>9.2}x",
                    label,
                    algo.name(),
                    n,
                    m.median_s * 1e3,
                    engine.flops(n) / m.median_s / 1e9,
                    rel,
                );
            }
        }
    }
    println!("\n(cute = the native HRPB engine; 'vs cute' > 1 means slower than cuTeSpMM)");
}
