//! Regenerates the paper's Fig. 9 box plots (throughput by synergy group ×
//! dense width × algorithm) and Table 2 (corpus synergy counts).
//!
//! `CUTESPMM_FULL=1 cargo bench --bench bench_fig9` for the full corpus.

use cutespmm::bench::experiments;

fn main() {
    let quick = std::env::var_os("CUTESPMM_FULL").is_none();
    let records = experiments::corpus_records(quick);
    println!("{}", experiments::table2(&records));
    println!("{}", experiments::fig9(&records));
}
