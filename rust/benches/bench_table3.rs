//! Regenerates the paper's Table 3: the 14 TC-GNN-paper matrices on the
//! modeled RTX 4090 at n ∈ {32, 64, 128} (GFLOPs for cuTeSpMM / TC-GNN /
//! Best-SC).

use cutespmm::bench::experiments;

fn main() {
    println!("{}", experiments::table34(3));
}
