//! Regenerates the paper's Table 4: the 13 TC-GNN-paper matrices on the
//! modeled A100 at n ∈ {32, 128, 512}.

use cutespmm::bench::experiments;

fn main() {
    println!("{}", experiments::table34(4));
}
