//! Regenerates the paper's Fig. 7: modeled OI_shmem (512α) vs cuTeSpMM
//! throughput at N ∈ {32, 128, 512} on both modeled GPUs.
//!
//! `CUTESPMM_FULL=1 cargo bench --bench bench_fig7` for the full corpus.

use cutespmm::bench::experiments;

fn main() {
    let quick = std::env::var_os("CUTESPMM_FULL").is_none();
    let records = experiments::corpus_records(quick);
    println!("{}", experiments::fig7(&records));
}
