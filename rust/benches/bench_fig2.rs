//! Regenerates the paper's Fig. 2: TC-GNN vs Best-SC scatter at N=128 on
//! both modeled GPUs, over the synthetic corpus.
//!
//! `cargo bench --bench bench_fig2` (quick 1/10 corpus by default;
//! set `CUTESPMM_FULL=1` for the full ~1100-matrix run).

use cutespmm::bench::experiments;

fn main() {
    let quick = std::env::var_os("CUTESPMM_FULL").is_none();
    let records = experiments::corpus_records(quick);
    println!("{}", experiments::fig2(&records));
}
