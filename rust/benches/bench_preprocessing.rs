//! Regenerates §6.3: measured preprocessing overhead vs one SpMM (N=128)
//! vs MatrixMarket read time, on this CPU.

use cutespmm::bench::experiments;

fn main() {
    println!("{}", experiments::preprocessing());
}
