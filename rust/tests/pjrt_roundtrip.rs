//! Integration tests of the full three-layer AOT path: HRPB feed → PJRT
//! executable (compiled from the Pallas/JAX HLO artifacts) → Rust results,
//! cross-checked against the native engine and the dense oracle.
//!
//! These tests skip (with a notice) when `make artifacts` has not run.

use cutespmm::coordinator::{Config, Coordinator, EnginePolicy};
use cutespmm::formats::{Coo, Dense};
use cutespmm::runtime;
use cutespmm::spmm::Algo;
use cutespmm::util::rng::Rng;
use std::sync::Arc;

fn artifacts_ready() -> bool {
    let ok = runtime::artifacts_available();
    if !ok {
        eprintln!("skipping PJRT integration test: run `make artifacts` first");
    }
    ok
}

#[test]
fn pjrt_matches_native_and_oracle_across_shapes() {
    if !artifacts_ready() {
        return;
    }
    let svc = runtime::PjrtService::start(runtime::default_artifacts_dir()).unwrap();
    let h = svc.handle();
    let mut rng = Rng::new(1);
    // shapes spanning several buckets, incl. awkward non-multiples
    for (m, k, n, d) in [
        (100, 200, 32, 0.05),
        (500, 510, 32, 0.01),
        (300, 400, 128, 0.02),
        (1000, 1800, 128, 0.004),
        (17, 33, 32, 0.2),
    ] {
        let coo = Coo::random(m, k, d, &mut rng);
        let b = Dense::random(k, n, &mut rng);
        let hrpb = Arc::new(cutespmm::hrpb::build_from_coo(&coo));
        let via_pjrt = h.spmm(hrpb, b.clone()).unwrap();
        let via_native = Algo::Hrpb.prepare(&coo).spmm(&b);
        let oracle = coo.to_dense().matmul(&b);
        assert!(via_pjrt.rel_fro_error(&oracle) < 1e-4, "pjrt vs oracle ({m}x{k} n={n})");
        assert!(via_pjrt.rel_fro_error(&via_native) < 1e-4, "pjrt vs native ({m}x{k} n={n})");
    }
}

#[test]
fn pjrt_under_concurrent_coordinator_traffic() {
    if !artifacts_ready() {
        return;
    }
    let svc = runtime::PjrtService::start(runtime::default_artifacts_dir()).unwrap();
    let coord = Arc::new(Coordinator::start(
        Config { workers: 3, engine: EnginePolicy::PreferPjrt, ..Default::default() },
        Some(svc.handle()),
    ));
    let mut rng = Rng::new(2);
    let coo = Coo::random(400, 500, 0.02, &mut rng);
    let id = coord.register("pjrt-mat", &coo);
    let dense = Arc::new(coo.to_dense());

    let mut saw_pjrt = false;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let coord = coord.clone();
            let dense = dense.clone();
            handles.push(s.spawn(move || {
                let mut any_pjrt = false;
                for i in 0..6 {
                    let b = Dense::random(500, 32, &mut Rng::new(t * 31 + i));
                    let want = dense.matmul(&b);
                    let resp = coord.call(id, b).unwrap();
                    assert!(resp.c.rel_fro_error(&want) < 1e-4);
                    any_pjrt |= resp.engine == "pjrt";
                }
                any_pjrt
            }));
        }
        for h in handles {
            saw_pjrt |= h.join().unwrap();
        }
    });
    assert!(saw_pjrt, "no request was served by the PJRT engine");
}

#[test]
fn pjrt_falls_back_to_native_on_oversize() {
    if !artifacts_ready() {
        return;
    }
    let svc = runtime::PjrtService::start(runtime::default_artifacts_dir()).unwrap();
    let coord = Coordinator::start(
        Config { workers: 1, engine: EnginePolicy::PreferPjrt, ..Default::default() },
        Some(svc.handle()),
    );
    let mut rng = Rng::new(3);
    // K = 9000 exceeds every bucket -> PJRT must fail -> fallback serves it
    let coo = Coo::random(300, 9000, 0.002, &mut rng);
    let id = coord.register("oversize", &coo);
    let b = Dense::random(9000, 32, &mut rng);
    let want = coo.to_dense().matmul(&b);
    let resp = coord.call(id, b).unwrap();
    assert_eq!(resp.engine, "cutespmm-native");
    assert!(resp.c.rel_fro_error(&want) < 1e-5);
    coord.shutdown();
}

#[test]
fn bucket_padding_is_inert_through_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let svc = runtime::PjrtService::start(runtime::default_artifacts_dir()).unwrap();
    let h = svc.handle();
    // two matrices identical except the second has fewer blocks (more
    // padding in-bucket); both must be exact
    let mut rng = Rng::new(4);
    let a1 = Coo::random(128, 300, 0.05, &mut rng);
    let a2 = Coo::random(48, 300, 0.01, &mut rng);
    for a in [a1, a2] {
        let b = Dense::random(300, 32, &mut rng);
        let want = a.to_dense().matmul(&b);
        let hrpb = Arc::new(cutespmm::hrpb::build_from_coo(&a));
        let got = h.spmm(hrpb, b).unwrap();
        assert!(got.rel_fro_error(&want) < 1e-4);
    }
}
