//! Integration tests of the L3 serving stack: coordinator + registry +
//! batcher + workers under load, failure injection, and backpressure.

use cutespmm::coordinator::{BatchPolicy, Config, Coordinator, EnginePolicy, MatrixId};
use cutespmm::formats::{Coo, Dense};
use cutespmm::qos::{Priority, QosConfig, RejectReason};
use cutespmm::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn coordinator(workers: usize, queue: usize) -> Coordinator {
    Coordinator::start(
        Config {
            workers,
            queue_capacity: queue,
            batch: BatchPolicy {
                max_batch_cols: 64,
                max_batch_reqs: 8,
                max_delay: Duration::from_millis(1),
            },
            engine: EnginePolicy::Native,
            qos: None,
            artifact_dir: None,
            ..Default::default()
        },
        None,
    )
}

#[test]
fn sustained_mixed_load_is_correct() {
    let coord = Arc::new(coordinator(4, 4096));
    let mut rng = Rng::new(1);
    let mats: Vec<(MatrixId, Coo)> = (0..3)
        .map(|i| {
            let coo = Coo::random(200 + i * 64, 300, 0.03, &mut rng);
            (coord.register(&format!("m{i}"), &coo), coo)
        })
        .collect();
    let denses: Vec<Dense> = mats.iter().map(|(_, c)| c.to_dense()).collect();

    std::thread::scope(|s| {
        for t in 0..6u64 {
            let coord = coord.clone();
            let mats = &mats;
            let denses = &denses;
            s.spawn(move || {
                let mut rng = Rng::new(100 + t);
                for i in 0..25 {
                    let mi = (t as usize + i) % mats.len();
                    let n = [8, 16, 32][i % 3];
                    let b = Dense::random(300, n, &mut rng);
                    let want = denses[mi].matmul(&b);
                    let resp = coord.call(mats[mi].0, b).unwrap();
                    assert!(resp.c.rel_fro_error(&want) < 1e-5);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.responses.load(Ordering::Relaxed), 150);
    assert_eq!(m.failures.load(Ordering::Relaxed), 0);
    // batching must actually happen under this concurrency
    let batches = m.batches.load(Ordering::Relaxed);
    assert!(batches < 150, "no batching occurred ({batches} batches for 150 reqs)");
}

#[test]
fn try_submit_backpressure() {
    // 1-capacity queue + a heavy matrix: try_submit must eventually reject
    let coord = Coordinator::start(
        Config {
            workers: 1,
            queue_capacity: 1,
            batch: BatchPolicy {
                max_batch_cols: 16,
                max_batch_reqs: 1,
                max_delay: Duration::from_millis(0),
            },
            engine: EnginePolicy::Native,
            qos: None,
            artifact_dir: None,
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::new(2);
    let coo = Coo::random(4096, 4096, 0.01, &mut rng);
    let id = coord.register("heavy", &coo);
    let mut accepted = 0;
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..64 {
        let b = Dense::random(4096, 16, &mut rng);
        match coord.try_submit(id, b) {
            Ok(rx) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(_) => rejected += 1,
        }
    }
    // all accepted requests must still complete
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    assert!(accepted > 0);
    assert!(rejected > 0, "queue of 1 never filled (accepted {accepted})");
    assert_eq!(coord.metrics().rejected.load(Ordering::Relaxed), rejected);
    coord.shutdown();
}

#[test]
fn failure_injection_bad_shapes_interleaved() {
    let coord = coordinator(2, 256);
    let mut rng = Rng::new(3);
    let coo = Coo::random(100, 120, 0.05, &mut rng);
    let id = coord.register("m", &coo);
    let dense = coo.to_dense();
    let mut ok = 0;
    let mut bad = 0;
    for i in 0..40 {
        let rows = if i % 5 == 0 { 37 } else { 120 }; // every 5th is malformed
        let b = Dense::random(rows, 8, &mut rng);
        match coord.call(id, b.clone()) {
            Ok(resp) => {
                ok += 1;
                assert!(resp.c.rel_fro_error(&dense.matmul(&b)) < 1e-5);
            }
            Err(_) => bad += 1,
        }
    }
    assert_eq!(ok, 32);
    assert_eq!(bad, 8);
    coord.shutdown();
}

#[test]
fn shutdown_drains_pending() {
    let coord = coordinator(1, 1024);
    let mut rng = Rng::new(4);
    let coo = Coo::random(256, 256, 0.02, &mut rng);
    let id = coord.register("m", &coo);
    let mut rxs = Vec::new();
    for _ in 0..20 {
        rxs.push(coord.submit(id, Dense::random(256, 8, &mut rng)));
    }
    coord.shutdown(); // must not drop queued work
    let mut served = 0;
    for rx in rxs {
        if let Ok(Ok(_)) = rx.recv() {
            served += 1;
        }
    }
    assert_eq!(served, 20, "shutdown dropped {} in-flight requests", 20 - served);
}

#[test]
fn auto_policy_serves_correctly_and_counts_routes() {
    use cutespmm::spmm::Algo;
    let coord = Coordinator::start(
        Config { workers: 2, engine: EnginePolicy::Auto, ..Default::default() },
        None,
    );
    // deterministic low-synergy structure: one nonzero per row panel
    let t: Vec<(usize, usize, f32)> = (0..128).map(|p| (p * 16, p * 16, 1.0)).collect();
    let low = Coo::from_triplets(2048, 2048, &t);
    let id = coord.register("low", &low);
    let plan = coord.registry().get(id).unwrap().plan.clone().expect("auto plans");
    assert!(
        Algo::scalar_core().contains(&plan.engine),
        "low synergy routed to {} ({})",
        plan.engine.name(),
        plan.rationale
    );

    let mut rng = Rng::new(1);
    let b = Dense::random(2048, 8, &mut rng);
    let want = low.to_dense().matmul(&b);
    let resp = coord.call(id, b).unwrap();
    assert!(resp.c.rel_fro_error(&want) < 1e-5);
    assert_eq!(resp.engine, plan.engine.name());
    assert!(coord.metrics().engine_requests(plan.engine) >= 1);
    // repeat registration under another name hits the plan cache
    let planner = coord.planner().unwrap().clone();
    let hits = planner.cache().stats().hits;
    let _ = coord.register("low-replica", &low);
    assert_eq!(planner.cache().stats().hits, hits + 1);
    coord.shutdown();
}

#[test]
fn qos_shutdown_rejects_queued_work_with_typed_errors() {
    // slow matrix + single worker: most of the flood is still queued when
    // shutdown lands, and every queued request must get a typed rejection
    // instead of being dropped on the floor
    let coord = Coordinator::start(
        Config {
            workers: 1,
            queue_capacity: 1024,
            batch: BatchPolicy {
                max_batch_cols: 16,
                max_batch_reqs: 1,
                max_delay: Duration::from_millis(0),
            },
            engine: EnginePolicy::Native,
            qos: Some(QosConfig {
                queue_capacity: 64,
                watermark_s: 0.0,
                default_deadline: None,
            }),
            artifact_dir: None,
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::new(20);
    let coo = Coo::random(4096, 4096, 0.01, &mut rng);
    let id = coord.register("heavy", &coo);
    let mut rxs = Vec::new();
    for _ in 0..32 {
        let b = Dense::random(4096, 16, &mut rng);
        match coord.submit_qos(id, b, Priority::Normal, None) {
            Ok(rx) => rxs.push(rx),
            Err((rejected, _)) => panic!("64-deep queue shed early: {rejected}"),
        }
    }
    coord.shutdown();
    let (mut served, mut rejected) = (0, 0);
    for rx in rxs {
        match rx.recv().expect("every admitted request gets a reply") {
            Ok(_) => served += 1,
            Err(e) => {
                assert!(e.contains("shutdown"), "unexpected error: {e}");
                rejected += 1;
            }
        }
    }
    assert_eq!(served + rejected, 32, "nothing may be dropped on the floor");
    assert!(rejected > 0, "shutdown under load should reject queued work");
}

#[test]
fn qos_high_priority_lane_is_served_and_counted() {
    let coord = Coordinator::start(
        Config {
            workers: 2,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            engine: EnginePolicy::Native,
            qos: Some(QosConfig {
                queue_capacity: 256,
                watermark_s: 0.0,
                default_deadline: Some(Duration::from_secs(30)),
            }),
            artifact_dir: None,
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::new(21);
    let coo = Coo::random(200, 300, 0.03, &mut rng);
    let dense = coo.to_dense();
    let id = coord.register("m", &coo);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..24 {
        let b = Dense::random(300, 8, &mut rng);
        expected.push(dense.matmul(&b));
        let pr = if i % 2 == 0 { Priority::High } else { Priority::Normal };
        rxs.push(coord.submit_qos(id, b, pr, None).expect("capacity 256 never fills here"));
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.c.rel_fro_error(&want) < 1e-5);
    }
    let m = coord.metrics();
    assert_eq!(m.responses.load(Ordering::Relaxed), 24);
    assert_eq!(m.qos[Priority::High.index()].admitted.load(Ordering::Relaxed), 12);
    assert_eq!(m.qos[Priority::Normal.index()].admitted.load(Ordering::Relaxed), 12);
    assert_eq!(m.shed_total(), 0);
    assert!(m.qos[Priority::High.index()].queue_wait.count() >= 1);
    let report = m.report();
    assert!(report.contains("qos=["), "{report}");
    assert!(report.contains("high: admitted=12"), "{report}");
    coord.shutdown();
    // unused reason indices stay accessible for reporting tools
    assert_eq!(RejectReason::all().len(), RejectReason::COUNT);
}

#[test]
fn tracing_captures_request_span_tree_and_chrome_export() {
    use cutespmm::trace::{self, TraceConfig};
    // the trace session is process-global: serialize against any other
    // tracing test in this binary
    let _session = trace::session_guard();
    let _ = trace::drain();
    let coord = Coordinator::start(
        Config {
            workers: 2,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            engine: EnginePolicy::Native,
            qos: Some(QosConfig {
                queue_capacity: 256,
                watermark_s: 0.0,
                default_deadline: None,
            }),
            artifact_dir: None,
            trace: TraceConfig {
                enabled: true,
                sample_rate: 1.0,
                kernel: true,
                ring_capacity: 1 << 14,
            },
        },
        None,
    );
    let mut rng = Rng::new(30);
    let coo = Coo::random(400, 300, 0.03, &mut rng);
    let id = coord.register("traced", &coo);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let b = Dense::random(300, 8, &mut rng);
        let pr = if i % 4 == 0 { Priority::High } else { Priority::Normal };
        rxs.push(coord.submit_qos(id, b, pr, None).expect("capacity 256 never fills here"));
    }
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    coord.shutdown();
    let tr = trace::drain();
    trace::disable();

    // the full request span tree is present: every request admits and
    // scatters; queue_wait/batch/exec cover the pipeline in between.
    // (>= because concurrent serving tests also record while the global
    // gate is on)
    assert!(tr.count("admit") >= 16, "sample_rate 1.0 traces every request");
    assert!(tr.count("scatter") >= 16);
    for stage in ["queue_wait", "batch", "exec"] {
        assert!(tr.count(stage) >= 1, "missing {stage} spans");
    }
    // kernel profiling spans from the HRPB engine's work units
    assert!(tr.count("unit") >= 1, "kernel tracing records HRPB unit spans");
    assert_eq!(tr.dropped, 0, "16 requests cannot overflow a 16k ring");

    // the Chrome export is valid JSON with one event per span plus
    // thread_name metadata
    let doc = cutespmm::util::json::parse(&tr.to_chrome_json().to_string())
        .expect("chrome export parses");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), tr.spans.len() + tr.threads.len());
}

#[test]
fn preprocess_once_amortization_visible() {
    let coord = coordinator(2, 256);
    let mut rng = Rng::new(5);
    let coo = Coo::random(2000, 2000, 0.005, &mut rng);
    let id = coord.register("amort", &coo);
    let entry = coord.registry().get(id).unwrap();
    let prep = entry.preprocess_time;

    // 30 requests reuse the single preprocessing
    let t0 = std::time::Instant::now();
    for _ in 0..30 {
        let b = Dense::random(2000, 16, &mut rng);
        coord.call(id, b).unwrap();
    }
    let serve_time = t0.elapsed();
    // §6.3's premise: prep is paid once; serving 30 requests does not pay it
    // 30 more times. (weak bound to stay robust on loaded CI machines)
    assert!(
        serve_time < prep * 30,
        "serving 30 reqs ({serve_time:?}) should beat 30x preprocessing ({:?})",
        prep * 30
    );
    assert_eq!(coord.registry().len(), 1);
    coord.shutdown();
}
