//! Cross-module integration tests: generators → HRPB → engines → synergy →
//! load balancing → cost models, exercised together on realistic matrices.

use cutespmm::formats::{Coo, Csr, Dense};
use cutespmm::gen::corpus::{specs, CorpusScale};
use cutespmm::gen::{named, Family, MatrixSpec};
use cutespmm::gpumodel::{algos, Machine, MatrixProfile};
use cutespmm::spmm::{Algo, SpmmEngine};
use cutespmm::synergy::Synergy;
use cutespmm::util::rng::Rng;

/// Every engine agrees with the dense oracle on every generator family.
#[test]
fn all_engines_agree_across_families() {
    let families = vec![
        Family::Banded { bandwidth: 12, band_fill: 0.6, noise: 0.01 },
        Family::Mesh { dims: 2 },
        Family::Mesh { dims: 3 },
        Family::Rmat { edge_factor: 6, skew: 0.57 },
        Family::Community { communities: 16, intra_degree: 8, inter_frac: 0.1 },
        Family::BlockDiag { unit: 20, unit_density: 0.3 },
        Family::Random { avg_degree: 5 },
    ];
    let mut rng = Rng::new(1);
    for (i, family) in families.into_iter().enumerate() {
        let spec = MatrixSpec { name: format!("it{i}"), rows: 1200, family, seed: i as u64 };
        let coo = spec.generate();
        let b = Dense::random(coo.cols, 24, &mut rng);
        let want = coo.to_dense().matmul(&b);
        for algo in Algo::all() {
            let got = algo.prepare(&coo).spmm(&b);
            let err = got.rel_fro_error(&want);
            assert!(err < 1e-4, "{} on family {i}: err {err}", algo.name());
        }
    }
}

/// The named GNN recipes flow through profile → model → prediction and the
/// executable engine agrees with the oracle.
#[test]
fn named_recipes_end_to_end() {
    for name in ["cora", "citeseer"] {
        let spec = named::by_name(name).unwrap().spec;
        let coo = spec.generate();
        let p = MatrixProfile::compute(&coo);
        assert!(p.nnz > 0);
        for m in [Machine::a100(), Machine::rtx4090()] {
            for algo in [Algo::Hrpb, Algo::TcGnn] {
                let pred = algos::predict(algo, &p, 32, &m);
                assert!(pred.gflops > 0.0 && pred.gflops < 200_000.0);
            }
        }
        let mut rng = Rng::new(3);
        let b = Dense::random(coo.cols, 16, &mut rng);
        let want = coo.to_dense().matmul(&b);
        assert!(Algo::Hrpb.prepare(&coo).spmm(&b).rel_fro_error(&want) < 1e-4);
    }
}

/// Corpus matrices stay structurally valid through HRPB round trips and the
/// synergy classes cover the expected spread.
#[test]
fn corpus_sample_roundtrips_and_classifies() {
    let all = specs(CorpusScale::Quick, 42);
    // a stratified handful (keep the test < a few seconds)
    let sample: Vec<_> = all.into_iter().step_by(23).take(6).collect();
    let mut seen = std::collections::HashSet::new();
    for spec in &sample {
        // scale rows down for the dense-oracle comparison
        let mut small = spec.clone();
        small.rows = 2000;
        if let Family::Community { ref mut communities, .. } = small.family {
            *communities = (*communities).min(200);
        }
        let coo = small.generate();
        if coo.nnz() == 0 {
            continue;
        }
        let hrpb = cutespmm::hrpb::build_from_coo(&coo);
        hrpb.validate().unwrap();
        let back = cutespmm::hrpb::decode::to_dense(&hrpb);
        assert_eq!(back.max_abs_diff(&coo.to_dense()), 0.0, "{}", spec.name);
        let stats = cutespmm::hrpb::stats::compute(&hrpb);
        seen.insert(Synergy::from_alpha(stats.alpha));
    }
    assert!(!seen.is_empty());
}

/// Load-balanced execution must agree with unbalanced execution on a
/// pathological skewed matrix (atomic consolidation correctness).
#[test]
fn balanced_execution_is_exact() {
    let mut t = Vec::new();
    let mut rng = Rng::new(9);
    for c in 0..3000usize {
        t.push((c % 16, (c * 3) % 8000, rng.nz_value()));
    }
    for r in (16..4000).step_by(16) {
        t.push((r, r % 8000, rng.nz_value()));
    }
    let coo = Coo::from_triplets(4000, 8000, &t);
    let hrpb = cutespmm::hrpb::build_from_coo(&coo);
    let b = Dense::random(8000, 32, &mut rng);

    use cutespmm::loadbalance as lb;
    use cutespmm::spmm::hrpb::HrpbEngine;
    let base = HrpbEngine::with_schedule(hrpb.clone(), lb::schedule_none(&hrpb)).spmm(&b);
    for schedule in [
        lb::schedule_sorted(&hrpb),
        lb::schedule_avg_split(&hrpb),
        lb::schedule_wave_aware(&hrpb, lb::Device { num_sms: 8, blocks_per_sm: 2 }),
    ] {
        let got = HrpbEngine::with_schedule(hrpb.clone(), schedule).spmm(&b);
        assert!(got.rel_fro_error(&base) < 1e-6);
    }
}

/// MatrixMarket IO round trip composed with the whole pipeline.
#[test]
fn mtx_io_to_engine() {
    let mut rng = Rng::new(17);
    let coo = Coo::random(500, 300, 0.02, &mut rng);
    let path = std::env::temp_dir().join("cutespmm_integration.mtx");
    cutespmm::formats::mtx::write_mtx(&path, &coo, Some("integration")).unwrap();
    let back = cutespmm::formats::mtx::read_mtx(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back.nnz(), coo.nnz());
    let b = Dense::random(300, 8, &mut rng);
    let want = coo.to_dense().matmul(&b);
    assert!(Algo::Hrpb.prepare(&back).spmm(&b).rel_fro_error(&want) < 1e-4);
}

/// The §4 paper claim: compaction means HRPB block count tracks *active*
/// columns, not the full K extent; and CSR conversion is lossless.
#[test]
fn compaction_and_formats_consistency() {
    let mut rng = Rng::new(23);
    // 100 columns active out of 100k
    let t: Vec<(usize, usize, f32)> =
        (0..1600).map(|i| (i % 64, (i % 100) * 1000, rng.nz_value())).collect();
    let coo = Coo::from_triplets(64, 100_000, &t);
    let csr = Csr::from_coo(&coo);
    assert_eq!(csr.to_coo().nnz(), coo.nnz());
    let hrpb = cutespmm::hrpb::build_from_coo(&coo);
    // per panel at most ceil(100/16) = 7 blocks
    let max_blocks = (0..hrpb.num_panels())
        .map(|p| hrpb.panel_blocks(p).len())
        .max()
        .unwrap();
    assert!(max_blocks <= 7, "compaction failed: {max_blocks} blocks in one panel");
}

/// Synergy ordering is monotone in structure: banded-dense > mesh > random.
#[test]
fn synergy_ordering_matches_structure() {
    let alpha = |family: Family| {
        let spec = MatrixSpec { name: "s".into(), rows: 8000, family, seed: 5 };
        let coo = spec.generate();
        cutespmm::hrpb::stats::compute(&cutespmm::hrpb::build_from_coo(&coo)).alpha
    };
    let fem = alpha(Family::Banded { bandwidth: 16, band_fill: 0.7, noise: 0.0 });
    let mesh = alpha(Family::Mesh { dims: 2 });
    let rand = alpha(Family::Random { avg_degree: 4 });
    assert!(fem > mesh, "fem {fem} mesh {mesh}");
    assert!(mesh > rand, "mesh {mesh} rand {rand}");
}
